//! Cost frontiers (§3.1) and the three operations FT manipulates them
//! with: **product**, **union** and **reduce** (Algorithm 1).
//!
//! A tuple is (memory, time, trace); the trace is a persistent,
//! structurally-shared provenance tree ([`Trace`]) recording which
//! parallelization configuration / edge-reuse option produced the tuple.
//! Unrolling a strategy (§3.2 "Unroll LDP and elimination") is a walk of
//! this tree — no separate per-elimination bookkeeping is needed, and
//! `Arc` sharing keeps memory linear in the number of algebra operations
//! rather than in strategies x operators.

use std::sync::Arc;

pub mod trace;
pub use trace::Trace;

/// Reduction mode: the full Pareto frontier (FT), or single-objective
/// truncations that turn the same machinery into the OptCNN (time-only)
/// and ToFu (memory-only) baselines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Pareto,
    TimeOnly,
    MemOnly,
}

/// One (partial-)strategy tuple `(S, m, t)`.
#[derive(Debug, Clone)]
pub struct Tuple {
    pub mem: f64,
    pub time: f64,
    pub trace: Arc<Trace>,
}

impl Tuple {
    pub fn new(mem: f64, time: f64, trace: Arc<Trace>) -> Self {
        Self { mem, time, trace }
    }

    /// Combine two tuples (costs add; traces pair up) — the elementwise
    /// step of the *product* operation.
    pub fn combine(&self, other: &Tuple) -> Tuple {
        Tuple {
            mem: self.mem + other.mem,
            time: self.time + other.time,
            trace: Trace::pair(&self.trace, &other.trace),
        }
    }
}

/// A cost frontier: tuples sorted by ascending memory, strictly descending
/// time (the invariant established by [`reduce`]).
#[derive(Debug, Clone, Default)]
pub struct Frontier {
    pub tuples: Vec<Tuple>,
}

impl Frontier {
    /// Frontier containing a single tuple.
    pub fn singleton(mem: f64, time: f64, trace: Arc<Trace>) -> Self {
        Self { tuples: vec![Tuple::new(mem, time, trace)] }
    }

    pub fn len(&self) -> usize {
        self.tuples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tuples.is_empty()
    }

    /// Minimum-time tuple (right end of the frontier).
    pub fn min_time(&self) -> Option<&Tuple> {
        self.tuples.last()
    }

    /// Minimum-memory tuple (left end of the frontier).
    pub fn min_mem(&self) -> Option<&Tuple> {
        self.tuples.first()
    }

    /// Minimum-time tuple subject to a memory budget.
    pub fn min_time_within(&self, mem_budget: f64) -> Option<&Tuple> {
        self.tuples.iter().rev().find(|t| t.mem <= mem_budget)
    }

    /// Check the frontier invariant (ascending mem, descending time).
    pub fn is_valid(&self) -> bool {
        self.tuples.windows(2).all(|w| w[0].mem < w[1].mem && w[0].time > w[1].time)
    }

    /// **Product** ⊗ (Cartesian; costs add, traces pair), reduced.
    ///
    /// Perf (§Perf opt-1): costs are combined and reduced *first*; trace
    /// nodes are allocated only for the surviving tuples. The naive
    /// combine-then-reduce allocates two `Arc`s per discarded combo, which
    /// dominated the LDP profile.
    pub fn product(&self, other: &Frontier, mode: Mode) -> Frontier {
        // Perf (§Perf opt-2): a product with a singleton frontier is a
        // uniform cost shift — it preserves the staircase invariant, so
        // the sort+scan can be skipped entirely. LDP multiplies by the
        // singleton operator frontier `F(o_i, s_i^p)` at every step, and
        // the eliminations by `F(o_i, s_i^k)`, so this path is hot.
        if mode == Mode::Pareto && other.len() == 1 {
            let b = &other.tuples[0];
            return Frontier {
                tuples: self
                    .tuples
                    .iter()
                    .map(|a| {
                        Tuple::new(a.mem + b.mem, a.time + b.time, Trace::pair(&a.trace, &b.trace))
                    })
                    .collect(),
            };
        }
        if mode == Mode::Pareto && self.len() == 1 {
            return other.product(self, mode);
        }
        let mut combos: Vec<(f64, f64, (u32, u32))> =
            Vec::with_capacity(self.len() * other.len());
        for (i, a) in self.tuples.iter().enumerate() {
            for (j, b) in other.tuples.iter().enumerate() {
                combos.push((a.mem + b.mem, a.time + b.time, (i as u32, j as u32)));
            }
        }
        let kept = reduce_by(combos, mode);
        Frontier {
            tuples: kept
                .into_iter()
                .map(|(mem, time, (i, j))| {
                    Tuple::new(
                        mem,
                        time,
                        Trace::pair(
                            &self.tuples[i as usize].trace,
                            &other.tuples[j as usize].trace,
                        ),
                    )
                })
                .collect(),
        }
    }

    /// **Union** ∪ (concatenate), reduced.
    pub fn union(&self, other: &Frontier, mode: Mode) -> Frontier {
        let mut out = Vec::with_capacity(self.len() + other.len());
        out.extend(self.tuples.iter().cloned());
        out.extend(other.tuples.iter().cloned());
        reduce(out, mode)
    }
}

/// Relative ε for frontier thinning: a tuple must improve time by at
/// least this factor over the previously kept tuple to stay on the
/// frontier.
///
/// The paper's complexity analysis rests on the *random order* assumption
/// (Assumption 1) under which frontiers stay `O(log K)`; real cost
/// surfaces are smooth and strongly structured, so exact Pareto sets can
/// grow into the millions and stall the DP. ε-dominance keeps the
/// staircase within a 0.5 % band of the exact frontier (each kept point is
/// a real strategy; only near-duplicate alternatives are dropped) and
/// bounds every frontier to `O(log(t_max/t_min)/ε)` points. The global
/// min-time and min-memory points are always preserved exactly.
pub const THIN_EPS: f64 = 5e-3;

/// **Reduce** (Algorithm 1 + ε-thinning): sort by ascending memory and
/// keep each tuple that improves the best time seen so far by at least
/// `THIN_EPS` (relative). Ties on memory keep the faster tuple.
/// `Mode::TimeOnly` / `Mode::MemOnly` truncate the result to the single
/// optimal tuple for that objective (OptCNN / ToFu).
pub fn reduce(tuples: Vec<Tuple>, mode: Mode) -> Frontier {
    let combos: Vec<(f64, f64, Tuple)> =
        tuples.into_iter().map(|t| (t.mem, t.time, t)).collect();
    Frontier { tuples: reduce_by(combos, mode).into_iter().map(|(_, _, t)| t).collect() }
}

/// Algorithm 1 over (mem, time, payload) triples — shared by [`reduce`]
/// (payload = full tuple) and [`Frontier::product`] (payload = index pair,
/// so traces are only allocated for survivors).
fn reduce_by<T: Clone>(mut items: Vec<(f64, f64, T)>, mode: Mode) -> Vec<(f64, f64, T)> {
    if items.is_empty() {
        return items;
    }
    match mode {
        Mode::TimeOnly => {
            let best = items
                .into_iter()
                .min_by(|a, b| (a.1, a.0).partial_cmp(&(b.1, b.0)).unwrap())
                .unwrap();
            return vec![best];
        }
        Mode::MemOnly => {
            let best = items
                .into_iter()
                .min_by(|a, b| (a.0, a.1).partial_cmp(&(b.0, b.1)).unwrap())
                .unwrap();
            return vec![best];
        }
        Mode::Pareto => {}
    }
    // Algorithm 1: ascending memory (time as tiebreak).
    items.sort_by(|a, b| (a.0, a.1).partial_cmp(&(b.0, b.1)).unwrap());
    // remember the global min-time item so thinning can never lose it.
    let best_time = items
        .iter()
        .min_by(|a, b| (a.1, a.0).partial_cmp(&(b.1, b.0)).unwrap())
        .cloned()
        .unwrap();
    let mut out: Vec<(f64, f64, T)> = Vec::new();
    let mut v = f64::INFINITY;
    for t in items {
        if t.1 < v * (1.0 - THIN_EPS) {
            v = t.1;
            // equal-memory entries: the sort guarantees the first (fastest)
            // wins; later equal-mem tuples have larger time and are skipped
            // by the time test unless mem strictly increased.
            if let Some(last) = out.last() {
                if last.0 == t.0 {
                    continue;
                }
            }
            out.push(t);
        }
    }
    // re-attach the exact min-time point if thinning dropped it.
    if let Some(last) = out.last() {
        if last.1 > best_time.1 {
            if last.0 == best_time.0 {
                *out.last_mut().unwrap() = best_time;
            } else {
                out.push(best_time);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest;
    use crate::util::rng::XorShift;

    fn tup(mem: f64, time: f64) -> Tuple {
        Tuple::new(mem, time, Trace::empty())
    }

    #[test]
    fn reduce_algorithm1() {
        // Figure-2 style: random points; frontier = lower-left staircase.
        let ts = vec![tup(1.0, 10.0), tup(2.0, 5.0), tup(3.0, 7.0), tup(4.0, 4.0), tup(5.0, 4.5)];
        let f = reduce(ts, Mode::Pareto);
        let pts: Vec<(f64, f64)> = f.tuples.iter().map(|t| (t.mem, t.time)).collect();
        assert_eq!(pts, vec![(1.0, 10.0), (2.0, 5.0), (4.0, 4.0)]);
        assert!(f.is_valid());
    }

    #[test]
    fn reduce_equal_memory_keeps_fastest() {
        let f = reduce(vec![tup(1.0, 5.0), tup(1.0, 3.0), tup(1.0, 9.0)], Mode::Pareto);
        assert_eq!(f.len(), 1);
        assert_eq!(f.tuples[0].time, 3.0);
    }

    #[test]
    fn modes_truncate() {
        let ts = vec![tup(1.0, 10.0), tup(2.0, 5.0), tup(4.0, 4.0)];
        let t = reduce(ts.clone(), Mode::TimeOnly);
        assert_eq!(t.len(), 1);
        assert_eq!(t.tuples[0].time, 4.0);
        let m = reduce(ts, Mode::MemOnly);
        assert_eq!(m.len(), 1);
        assert_eq!(m.tuples[0].mem, 1.0);
    }

    #[test]
    fn product_adds_costs() {
        let a = reduce(vec![tup(1.0, 4.0), tup(2.0, 2.0)], Mode::Pareto);
        let b = reduce(vec![tup(10.0, 40.0), tup(20.0, 20.0)], Mode::Pareto);
        let p = a.product(&b, Mode::Pareto);
        assert!(p.is_valid());
        // best-time combo present:
        assert_eq!(p.min_time().unwrap().time, 22.0);
        assert_eq!(p.min_mem().unwrap().mem, 11.0);
    }

    #[test]
    fn min_time_within_budget() {
        let f = reduce(vec![tup(1.0, 10.0), tup(2.0, 5.0), tup(4.0, 4.0)], Mode::Pareto);
        assert_eq!(f.min_time_within(3.0).unwrap().time, 5.0);
        assert_eq!(f.min_time_within(100.0).unwrap().time, 4.0);
        assert!(f.min_time_within(0.5).is_none());
    }

    /// Property (Definition 1): every input tuple is dominated by some
    /// frontier tuple, and no frontier tuple dominates another.
    #[test]
    fn prop_reduce_is_minimal_dominating_set() {
        ptest::quick("reduce-dominates", |rng: &mut XorShift| {
            let n = rng.range(1, 60);
            let tuples: Vec<Tuple> =
                (0..n).map(|_| tup((rng.below(30) + 1) as f64, (rng.below(30) + 1) as f64)).collect();
            let f = reduce(tuples.clone(), Mode::Pareto);
            crate::prop_assert!(f.is_valid(), "invariant violated");
            for t in &tuples {
                let dominated = f
                    .tuples
                    .iter()
                    .any(|ft| ft.mem <= t.mem && ft.time <= t.time);
                crate::prop_assert!(dominated, "tuple ({},{}) not dominated", t.mem, t.time);
            }
            for (i, a) in f.tuples.iter().enumerate() {
                for (j, b) in f.tuples.iter().enumerate() {
                    if i != j {
                        let dom = a.mem <= b.mem && a.time <= b.time;
                        crate::prop_assert!(!dom, "frontier not minimal");
                    }
                }
            }
            Ok(())
        });
    }

    /// Property: product ⊗ is commutative in costs and reduce(product) of
    /// frontiers equals reduce over the raw cross-join.
    #[test]
    fn prop_product_equals_crossjoin() {
        ptest::quick("product-crossjoin", |rng: &mut XorShift| {
            let mk = |rng: &mut XorShift| -> Vec<Tuple> {
                (0..rng.range(1, 12))
                    .map(|_| tup((rng.below(20) + 1) as f64, (rng.below(20) + 1) as f64))
                    .collect()
            };
            let a = reduce(mk(rng), Mode::Pareto);
            let b = reduce(mk(rng), Mode::Pareto);
            let p1 = a.product(&b, Mode::Pareto);
            let p2 = b.product(&a, Mode::Pareto);
            crate::prop_assert!(p1.len() == p2.len(), "commutativity size");
            for (x, y) in p1.tuples.iter().zip(&p2.tuples) {
                crate::prop_assert!(
                    x.mem == y.mem && x.time == y.time,
                    "commutativity content"
                );
            }
            Ok(())
        });
    }

    /// Lemma 2 sanity: frontier of K random tuples has ~O(log K) size.
    #[test]
    fn expected_frontier_size_logarithmic() {
        let mut rng = XorShift::new(99);
        let k = 4096;
        let mut total = 0usize;
        let reps = 20;
        for _ in 0..reps {
            let tuples: Vec<Tuple> =
                (0..k).map(|_| tup(rng.f64(), rng.f64())).collect();
            total += reduce(tuples, Mode::Pareto).len();
        }
        let avg = total as f64 / reps as f64;
        let expect = (1..=k).map(|i| 1.0 / i as f64).sum::<f64>(); // H_K ≈ ln K
        assert!((avg - expect).abs() < 4.0, "avg {avg} vs H_K {expect}");
    }
}
