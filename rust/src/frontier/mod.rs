//! Cost frontiers (§3.1) and the three operations FT manipulates them
//! with: **product**, **union** and **reduce** (Algorithm 1), generalized
//! from the paper's two objectives to three.
//!
//! A tuple is (memory, time, dollars, trace); the trace is a persistent,
//! structurally-shared provenance tree ([`Trace`]) recording which
//! parallelization configuration / edge-reuse option produced the tuple.
//! Unrolling a strategy (§3.2 "Unroll LDP and elimination") is a walk of
//! this tree — no separate per-elimination bookkeeping is needed, and
//! `Arc` sharing keeps memory linear in the number of algebra operations
//! rather than in strategies x operators.
//!
//! ## Engine layout
//!
//! The operations here are thin views over a struct-of-arrays kernel (the
//! private `soa` module): the three objectives live in three contiguous
//! `f64` lanes, dominance and ε-thinning are linear sweeps over those
//! lanes, sorting happens on a `u32` permutation so tuple payloads (and
//! their `Arc` traces) move only when they survive, and
//! [`Frontier::union_many`] merges the parts' already-sorted runs
//! divide-and-conquer style instead of re-sorting the concatenation. The
//! boxed pre-SoA engine is frozen verbatim in [`reference`] as the oracle
//! the differential suite (`rust/tests/frontier_diff.rs`) compares
//! against bit-for-bit.
//!
//! ## The third objective: monetary cost
//!
//! The paper motivates auto-parallelism with cloud users who want to
//! "improve the efficiency or reduce the cost" of training. [`Tuple::cost`]
//! carries dollars as a first-class objective: leaves are stamped by the
//! search space when the cluster is priced (`FtOptions::usd_hour`),
//! [`Tuple::combine`] adds costs exactly like memory and time, and
//! [`reduce`] applies 3-D Pareto dominance with per-objective ε-thinning.
//! Within a single fixed-price search, cost is proportional to time, so
//! 3-D dominance degenerates to the paper's 2-D staircase and frontier
//! sizes do not grow; the third dimension earns its keep when frontiers
//! from *differently priced clusters* (cluster sizes, device generations,
//! spot vs on-demand) are unioned — a point that is slower but cheaper
//! survives a union where 2-D dominance would drop it, which is exactly
//! what `exp provision` reports. Unpriced searches leave `cost == 0.0`
//! everywhere, and every operation then reproduces the 2-D behavior
//! bit-for-bit.

use std::sync::Arc;

pub use crate::obs::provenance as trace;
pub use crate::obs::provenance::Trace;

pub mod reference;
mod soa;

use soa::Lanes;

/// Reduction mode: the full Pareto frontier (FT), or single-objective
/// truncations that turn the same machinery into the OptCNN (time-only)
/// and ToFu (memory-only) baselines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    /// Keep the full (memory, time, cost) Pareto frontier (FT).
    Pareto,
    /// Keep only the minimum-time tuple (the OptCNN baseline).
    TimeOnly,
    /// Keep only the minimum-memory tuple (the ToFu baseline).
    MemOnly,
}

/// One (partial-)strategy tuple `(S, m, t, $)`.
#[derive(Debug, Clone)]
pub struct Tuple {
    /// Peak per-device memory in bytes.
    pub mem: f64,
    /// Per-iteration execution time in seconds.
    pub time: f64,
    /// Monetary cost in dollars (per iteration, when the search space is
    /// priced via `FtOptions::usd_hour`); 0.0 on unpriced searches, in
    /// which case every frontier operation reduces to the paper's
    /// two-objective behavior.
    pub cost: f64,
    /// Provenance of the tuple (which configs / reuse options built it).
    pub trace: Arc<Trace>,
}

impl Tuple {
    /// Unpriced tuple (`cost = 0.0`) — the paper's two-objective form.
    pub fn new(mem: f64, time: f64, trace: Arc<Trace>) -> Self {
        Self { mem, time, cost: 0.0, trace }
    }

    /// Tuple with an explicit dollar cost.
    pub fn with_cost(mem: f64, time: f64, cost: f64, trace: Arc<Trace>) -> Self {
        Self { mem, time, cost, trace }
    }

    /// Combine two tuples (all three costs add; traces pair up) — the
    /// elementwise step of the *product* operation.
    pub fn combine(&self, other: &Tuple) -> Tuple {
        Tuple {
            mem: self.mem + other.mem,
            time: self.time + other.time,
            cost: self.cost + other.cost,
            trace: Trace::pair(&self.trace, &other.trace),
        }
    }

    /// Exact 3-D Pareto dominance: `self` is no worse than `other` on
    /// every objective (and they may be equal on all three).
    pub fn dominates(&self, other: &Tuple) -> bool {
        self.mem <= other.mem && self.time <= other.time && self.cost <= other.cost
    }
}

/// A cost frontier: mutually non-dominated tuples sorted by ascending
/// (memory, time, cost) — the invariant established by [`reduce`]. With
/// all costs zero this is the paper's staircase (strictly ascending
/// memory, strictly descending time).
#[derive(Debug, Clone, Default)]
pub struct Frontier {
    /// The tuples, sorted ascending by (mem, time, cost).
    pub tuples: Vec<Tuple>,
}

impl Frontier {
    /// Frontier containing a single unpriced tuple.
    pub fn singleton(mem: f64, time: f64, trace: Arc<Trace>) -> Self {
        Self { tuples: vec![Tuple::new(mem, time, trace)] }
    }

    /// Number of tuples on the frontier.
    pub fn len(&self) -> usize {
        self.tuples.len()
    }

    /// Is the frontier empty?
    pub fn is_empty(&self) -> bool {
        self.tuples.is_empty()
    }

    /// Minimum-time tuple (ties broken toward lower memory, then cost).
    pub fn min_time(&self) -> Option<&Tuple> {
        self.tuples.iter().min_by(|a, b| {
            (a.time, a.mem, a.cost).partial_cmp(&(b.time, b.mem, b.cost)).unwrap()
        })
    }

    /// Minimum-memory tuple (left end of the frontier).
    pub fn min_mem(&self) -> Option<&Tuple> {
        self.tuples.first()
    }

    /// Minimum-cost tuple (ties broken toward lower memory, then time).
    pub fn min_cost(&self) -> Option<&Tuple> {
        self.tuples.iter().min_by(|a, b| {
            (a.cost, a.mem, a.time).partial_cmp(&(b.cost, b.mem, b.time)).unwrap()
        })
    }

    /// Minimum-time tuple subject to a memory budget.
    pub fn min_time_within(&self, mem_budget: f64) -> Option<&Tuple> {
        self.tuples.iter().filter(|t| t.mem <= mem_budget).min_by(|a, b| {
            (a.time, a.mem, a.cost).partial_cmp(&(b.time, b.mem, b.cost)).unwrap()
        })
    }

    /// Cheapest tuple whose time meets `deadline` (and memory fits
    /// `mem_budget`) — the provisioning question "cheapest strategy that
    /// trains in time".
    pub fn min_cost_within(&self, mem_budget: f64, deadline: f64) -> Option<&Tuple> {
        self.tuples
            .iter()
            .filter(|t| t.mem <= mem_budget && t.time <= deadline)
            .min_by(|a, b| {
                (a.cost, a.time, a.mem).partial_cmp(&(b.cost, b.time, b.mem)).unwrap()
            })
    }

    /// Fastest tuple whose cost fits `budget_usd` (and memory fits
    /// `mem_budget`) — the provisioning question "fastest strategy money
    /// can buy".
    pub fn min_time_within_cost(&self, mem_budget: f64, budget_usd: f64) -> Option<&Tuple> {
        self.tuples
            .iter()
            .filter(|t| t.mem <= mem_budget && t.cost <= budget_usd)
            .min_by(|a, b| {
                (a.time, a.cost, a.mem).partial_cmp(&(b.time, b.cost, b.mem)).unwrap()
            })
    }

    /// Check the frontier invariant: sorted by ascending (mem, time, cost)
    /// and mutually non-dominated (for all-zero costs this is exactly the
    /// paper's staircase: strictly ascending memory, strictly descending
    /// time).
    pub fn is_valid(&self) -> bool {
        let sorted = self.tuples.windows(2).all(|w| {
            (w[0].mem, w[0].time, w[0].cost) <= (w[1].mem, w[1].time, w[1].cost)
        });
        if !sorted {
            return false;
        }
        for (i, a) in self.tuples.iter().enumerate() {
            for (j, b) in self.tuples.iter().enumerate() {
                if i != j && a.dominates(b) {
                    return false;
                }
            }
        }
        true
    }

    /// **Product** ⊗ (Cartesian; costs add, traces pair), reduced.
    ///
    /// Perf (§Perf opt-1): costs are combined into the objective lanes and
    /// reduced *first*; trace nodes are allocated only for the surviving
    /// tuples. The naive combine-then-reduce allocates two `Arc`s per
    /// discarded combo, which dominated the LDP profile.
    pub fn product(&self, other: &Frontier, mode: Mode) -> Frontier {
        // Perf (§Perf opt-2): a product with a singleton frontier is a
        // uniform cost shift — it preserves dominance relations and the
        // sort order, so the sort+scan can be skipped entirely. LDP
        // multiplies by the singleton operator frontier `F(o_i, s_i^p)` at
        // every step, and the eliminations by `F(o_i, s_i^k)`, so this
        // path is hot.
        if mode == Mode::Pareto && other.len() == 1 {
            let b = &other.tuples[0];
            return Frontier { tuples: self.tuples.iter().map(|a| a.combine(b)).collect() };
        }
        if mode == Mode::Pareto && self.len() == 1 {
            return other.product(self, mode);
        }
        // row-major combos: position p encodes the pair (p / m, p % m), so
        // no per-combo payload is materialized at all.
        let m = other.len();
        let mut lanes = Lanes::with_capacity(self.len() * m);
        for a in &self.tuples {
            for b in &other.tuples {
                lanes.push(a.mem + b.mem, a.time + b.time, a.cost + b.cost);
            }
        }
        let kept = soa::reduce_indices(&lanes, mode, None);
        Frontier {
            tuples: kept
                .into_iter()
                .map(|p| {
                    let p = p as usize;
                    Tuple::with_cost(
                        lanes.mem[p],
                        lanes.time[p],
                        lanes.cost[p],
                        Trace::pair(&self.tuples[p / m].trace, &other.tuples[p % m].trace),
                    )
                })
                .collect(),
        }
    }

    /// **Union** ∪ (concatenate), reduced.
    pub fn union(&self, other: &Frontier, mode: Mode) -> Frontier {
        Frontier::union_many(vec![self.clone(), other.clone()], mode)
    }

    /// **Union** over any number of frontiers at once — bit-identical to
    /// [`reduce`] over the concatenation of all parts, but executed as a
    /// divide-and-conquer merge of the parts' already-sorted runs (with a
    /// fallback to a full stable sort when a part is unsorted), so
    /// unioning k reduced frontiers costs a merge rather than a fresh
    /// sort. The LDP solver and the elimination engine accumulate their
    /// per-configuration products with this.
    pub fn union_many(parts: Vec<Frontier>, mode: Mode) -> Frontier {
        let total: usize = parts.iter().map(Frontier::len).sum();
        let mut lanes = Lanes::with_capacity(total);
        let mut runs: Vec<u32> = Vec::with_capacity(parts.len());
        let mut tuples: Vec<Tuple> = Vec::with_capacity(total);
        for part in parts {
            for t in part.tuples {
                lanes.push(t.mem, t.time, t.cost);
                tuples.push(t);
            }
            runs.push(lanes.len() as u32);
        }
        let kept = soa::reduce_indices(&lanes, mode, Some(&runs));
        Frontier { tuples: gather(tuples, &kept) }
    }
}

/// Relative ε for frontier thinning: a tuple survives only if no kept
/// tuple is within this relative factor of beating it on *every*
/// non-memory objective.
///
/// The paper's complexity analysis rests on the *random order* assumption
/// (Assumption 1) under which frontiers stay `O(log K)`; real cost
/// surfaces are smooth and strongly structured, so exact Pareto sets can
/// grow into the millions and stall the DP. ε-dominance keeps the
/// staircase within a 0.5 % band of the exact frontier (each kept point is
/// a real strategy; only near-duplicate alternatives are dropped) and
/// bounds every frontier to `O(log(t_max/t_min)/ε)` points per objective.
/// The global minimum memory, time and cost *values* are always achieved
/// exactly by some kept tuple (thinning may substitute a different tuple
/// attaining the same extreme — e.g. one with the same minimal cost but
/// more memory — which is the standard ε-dominance approximation).
pub const THIN_EPS: f64 = 5e-3;

/// **Reduce** (Algorithm 1 + ε-thinning, generalized to three
/// objectives): sort by ascending memory and keep each tuple not
/// ε-dominated by an already-kept tuple — kept `q` ε-dominates `t` when
/// `q.time·(1-ε) ≤ t.time` *and* `q.cost·(1-ε) ≤ t.cost` (the memory
/// condition is implied by the sort order). With all costs equal this is
/// exactly the paper's staircase scan. Ties on memory keep the faster
/// tuple. `Mode::TimeOnly` / `Mode::MemOnly` truncate the result to the
/// single optimal tuple for that objective (OptCNN / ToFu).
///
/// Sorting and scanning run over the struct-of-arrays lanes; the boxed
/// tuples move once, at the final survivor gather.
pub fn reduce(tuples: Vec<Tuple>, mode: Mode) -> Frontier {
    let mut lanes = Lanes::with_capacity(tuples.len());
    for t in &tuples {
        lanes.push(t.mem, t.time, t.cost);
    }
    let kept = soa::reduce_indices(&lanes, mode, None);
    Frontier { tuples: gather(tuples, &kept) }
}

/// Exact 3-D Pareto filter over raw `(mem, time, cost)` points: indices of
/// the points no other point dominates (duplicates keep the lowest
/// index). No ε-thinning — used by `exp provision` and tests to *verify*
/// Pareto-optimality of reported points rather than to thin search
/// frontiers.
///
/// Runs as a sort-based sweep (O(n log n + n·f) for frontier size f); the
/// original quadratic pairwise scan survives as
/// [`reference::pareto_indices`], and the differential tests pin the two
/// to identical index sets on adversarial inputs.
pub fn pareto_indices(points: &[(f64, f64, f64)]) -> Vec<usize> {
    soa::pareto_sweep(points)
}

/// Move the tuples at the `kept` positions (each position appears at most
/// once) out of `tuples`, in `kept` order, without cloning traces.
fn gather(tuples: Vec<Tuple>, kept: &[u32]) -> Vec<Tuple> {
    let mut slots: Vec<Option<Tuple>> = tuples.into_iter().map(Some).collect();
    kept.iter().map(|&p| slots[p as usize].take().expect("survivor index repeated")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest;
    use crate::util::rng::XorShift;

    fn tup(mem: f64, time: f64) -> Tuple {
        Tuple::new(mem, time, Trace::empty())
    }

    fn tup3(mem: f64, time: f64, cost: f64) -> Tuple {
        Tuple::with_cost(mem, time, cost, Trace::empty())
    }

    #[test]
    fn reduce_algorithm1() {
        // Figure-2 style: random points; frontier = lower-left staircase.
        let ts = vec![tup(1.0, 10.0), tup(2.0, 5.0), tup(3.0, 7.0), tup(4.0, 4.0), tup(5.0, 4.5)];
        let f = reduce(ts, Mode::Pareto);
        let pts: Vec<(f64, f64)> = f.tuples.iter().map(|t| (t.mem, t.time)).collect();
        assert_eq!(pts, vec![(1.0, 10.0), (2.0, 5.0), (4.0, 4.0)]);
        assert!(f.is_valid());
    }

    #[test]
    fn reduce_equal_memory_keeps_fastest() {
        let f = reduce(vec![tup(1.0, 5.0), tup(1.0, 3.0), tup(1.0, 9.0)], Mode::Pareto);
        assert_eq!(f.len(), 1);
        assert_eq!(f.tuples[0].time, 3.0);
    }

    #[test]
    fn modes_truncate() {
        let ts = vec![tup(1.0, 10.0), tup(2.0, 5.0), tup(4.0, 4.0)];
        let t = reduce(ts.clone(), Mode::TimeOnly);
        assert_eq!(t.len(), 1);
        assert_eq!(t.tuples[0].time, 4.0);
        let m = reduce(ts, Mode::MemOnly);
        assert_eq!(m.len(), 1);
        assert_eq!(m.tuples[0].mem, 1.0);
    }

    #[test]
    fn product_adds_costs() {
        let a = reduce(vec![tup(1.0, 4.0), tup(2.0, 2.0)], Mode::Pareto);
        let b = reduce(vec![tup(10.0, 40.0), tup(20.0, 20.0)], Mode::Pareto);
        let p = a.product(&b, Mode::Pareto);
        assert!(p.is_valid());
        // best-time combo present:
        assert_eq!(p.min_time().unwrap().time, 22.0);
        assert_eq!(p.min_mem().unwrap().mem, 11.0);
    }

    #[test]
    fn product_adds_dollar_costs() {
        let a = reduce(vec![tup3(1.0, 4.0, 1.5), tup3(2.0, 2.0, 3.0)], Mode::Pareto);
        let b = reduce(vec![tup3(10.0, 40.0, 2.0)], Mode::Pareto);
        let p = a.product(&b, Mode::Pareto);
        assert_eq!(p.min_cost().unwrap().cost, 3.5);
        assert_eq!(p.min_time().unwrap().cost, 5.0);
    }

    #[test]
    fn min_time_within_budget() {
        let f = reduce(vec![tup(1.0, 10.0), tup(2.0, 5.0), tup(4.0, 4.0)], Mode::Pareto);
        assert_eq!(f.min_time_within(3.0).unwrap().time, 5.0);
        assert_eq!(f.min_time_within(100.0).unwrap().time, 4.0);
        assert!(f.min_time_within(0.5).is_none());
    }

    // ------------------------------------------------- edge cases (PR 3)

    #[test]
    fn empty_frontier_is_harmless() {
        let e = reduce(Vec::new(), Mode::Pareto);
        assert!(e.is_empty());
        assert_eq!(e.len(), 0);
        assert!(e.is_valid(), "the empty frontier is trivially valid");
        assert!(e.min_time().is_none());
        assert!(e.min_mem().is_none());
        assert!(e.min_cost().is_none());
        assert!(e.min_time_within(1e30).is_none());
        assert!(e.min_cost_within(1e30, 1e30).is_none());
        // products and unions with the empty frontier are empty / identity.
        let f = reduce(vec![tup(1.0, 2.0)], Mode::Pareto);
        assert!(f.product(&e, Mode::Pareto).is_empty());
        assert_eq!(f.union(&e, Mode::Pareto).len(), 1);
        assert!(reduce(Vec::new(), Mode::TimeOnly).is_empty());
        assert!(reduce(Vec::new(), Mode::MemOnly).is_empty());
    }

    #[test]
    fn single_point_frontier() {
        let f = reduce(vec![tup3(2.0, 3.0, 4.0)], Mode::Pareto);
        assert_eq!(f.len(), 1);
        assert!(f.is_valid());
        assert_eq!(f.min_time().unwrap().time, 3.0);
        assert_eq!(f.min_mem().unwrap().mem, 2.0);
        assert_eq!(f.min_cost().unwrap().cost, 4.0);
        // all selectors agree on the only point.
        assert_eq!(f.min_cost_within(2.0, 3.0).unwrap().cost, 4.0);
        assert!(f.min_cost_within(1.0, 3.0).is_none(), "memory budget filters");
        assert!(f.min_time_within_cost(2.0, 1.0).is_none(), "dollar budget filters");
    }

    #[test]
    fn duplicate_mem_time_pairs_collapse_to_one() {
        // exact duplicates in (mem, time) — and in cost — keep one tuple.
        let f = reduce(
            vec![tup(1.0, 5.0), tup(1.0, 5.0), tup(1.0, 5.0), tup(2.0, 1.0), tup(2.0, 1.0)],
            Mode::Pareto,
        );
        let pts: Vec<(f64, f64)> = f.tuples.iter().map(|t| (t.mem, t.time)).collect();
        assert_eq!(pts, vec![(1.0, 5.0), (2.0, 1.0)]);
        assert!(f.is_valid());
        // duplicate (mem, time) differing only in cost: cheaper one wins.
        let g = reduce(vec![tup3(1.0, 5.0, 9.0), tup3(1.0, 5.0, 2.0)], Mode::Pareto);
        assert_eq!(g.len(), 1);
        assert_eq!(g.tuples[0].cost, 2.0);
    }

    /// The PR's headline property: a point strictly dominated in the
    /// (mem, time) plane but cheapest in dollars is 2-D-dead yet must
    /// survive a 3-D reduce.
    #[test]
    fn point_dominated_in_2d_survives_in_3d() {
        let cheap_slow = tup3(4.0, 9.0, 1.0); // dominated by (2, 3) in 2-D
        let fast = tup3(2.0, 3.0, 10.0);
        let small = tup3(1.0, 20.0, 8.0);
        let f = reduce(vec![fast.clone(), cheap_slow.clone(), small.clone()], Mode::Pareto);
        assert_eq!(f.len(), 3, "all three are 3-D Pareto-optimal: {:?}", f.tuples);
        assert!(f.is_valid());
        assert_eq!(f.min_cost().unwrap().cost, 1.0, "the 2-D-dominated point survives");
        // sanity: with costs zeroed the same point dies.
        let f2 = reduce(vec![tup(2.0, 3.0), tup(4.0, 9.0), tup(1.0, 20.0)], Mode::Pareto);
        assert_eq!(f2.len(), 2);
    }

    #[test]
    fn pareto_indices_exact_filter() {
        let pts = vec![
            (1.0, 1.0, 1.0), // optimal
            (2.0, 2.0, 2.0), // dominated by 0
            (0.5, 3.0, 3.0), // optimal (min mem)
            (1.0, 1.0, 1.0), // duplicate of 0 -> only the first kept
            (3.0, 0.5, 9.0), // optimal (min time)
        ];
        assert_eq!(pareto_indices(&pts), vec![0, 2, 4]);
        assert!(pareto_indices(&[]).is_empty());
    }

    /// Satellite: the sort-based sweep must pin the exact index sets of
    /// the retired pairwise scan on adversarial inputs — duplicates,
    /// colinear points, ±0.0 — and on random clouds dense with ties.
    #[test]
    fn pareto_indices_adversarial_matches_reference() {
        let cases: Vec<Vec<(f64, f64, f64)>> = vec![
            vec![(1.0, 1.0, 1.0); 5],
            vec![(1.0, 2.0, 3.0), (2.0, 3.0, 4.0), (3.0, 4.0, 5.0), (1.0, 2.0, 3.0)],
            vec![(0.0, -0.0, 0.0), (-0.0, 0.0, 0.0), (0.0, 0.0, -0.0)],
            vec![(1.0, 5.0, 0.0), (2.0, 4.0, 0.0), (3.0, 3.0, 0.0), (2.0, 4.0, 0.0)],
            vec![(5.0, 1.0, 1.0), (4.0, 2.0, 1.0), (3.0, 3.0, 1.0), (2.0, 4.0, 1.0)],
            Vec::new(),
        ];
        for pts in &cases {
            assert_eq!(pareto_indices(pts), reference::pareto_indices(pts), "case {pts:?}");
        }
        ptest::quick("pareto-sweep-diff", |rng: &mut XorShift| {
            let n = rng.range(0, 40);
            let pts: Vec<(f64, f64, f64)> = (0..n)
                .map(|_| (rng.below(6) as f64, rng.below(6) as f64, rng.below(6) as f64))
                .collect();
            crate::prop_assert!(
                pareto_indices(&pts) == reference::pareto_indices(&pts),
                "sweep != pairwise on {:?}",
                pts
            );
            Ok(())
        });
    }

    /// [`Frontier::union_many`]'s contract: bit-identical to one reduce
    /// over the concatenation of all parts, whichever merge path it takes.
    #[test]
    fn union_many_matches_reduce_of_concatenation() {
        ptest::quick("union-many-concat", |rng: &mut XorShift| {
            let mk = |rng: &mut XorShift| -> Frontier {
                let n = rng.range(0, 10);
                let ts: Vec<Tuple> = (0..n)
                    .map(|_| {
                        let c = rng.below(3) as f64;
                        tup3((rng.below(20) + 1) as f64, (rng.below(20) + 1) as f64, c)
                    })
                    .collect();
                reduce(ts, Mode::Pareto)
            };
            let parts: Vec<Frontier> = (0..rng.range(1, 6)).map(|_| mk(rng)).collect();
            let all: Vec<Tuple> = parts.iter().flat_map(|f| f.tuples.iter().cloned()).collect();
            let direct = reduce(all, Mode::Pareto);
            let merged = Frontier::union_many(parts, Mode::Pareto);
            crate::prop_assert!(merged.len() == direct.len(), "length mismatch");
            for (x, y) in merged.tuples.iter().zip(&direct.tuples) {
                crate::prop_assert!(
                    x.mem.to_bits() == y.mem.to_bits()
                        && x.time.to_bits() == y.time.to_bits()
                        && x.cost.to_bits() == y.cost.to_bits(),
                    "tuple bits differ"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn extremes_always_survive_thinning() {
        // a dense cloud within ε of each other plus distinct extremes.
        let mut ts: Vec<Tuple> = (0..50)
            .map(|i| tup3(10.0 + i as f64 * 1e-4, 5.0 + i as f64 * 1e-4, 7.0))
            .collect();
        ts.push(tup3(100.0, 1.0, 50.0)); // exact min-time
        ts.push(tup3(50.0, 50.0, 0.25)); // exact min-cost
        let f = reduce(ts, Mode::Pareto);
        assert!(f.is_valid());
        assert_eq!(f.min_time().unwrap().time, 1.0);
        assert_eq!(f.min_cost().unwrap().cost, 0.25);
        assert_eq!(f.min_mem().unwrap().mem, 10.0);
    }

    /// Property (Definition 1, 3-D): every input tuple is dominated by
    /// some frontier tuple, and no frontier tuple dominates another.
    #[test]
    fn prop_reduce_is_minimal_dominating_set() {
        ptest::quick("reduce-dominates", |rng: &mut XorShift| {
            let n = rng.range(1, 60);
            let with_cost = rng.below(2) == 1;
            let tuples: Vec<Tuple> = (0..n)
                .map(|_| {
                    let c = if with_cost { (rng.below(30) + 1) as f64 } else { 0.0 };
                    tup3((rng.below(30) + 1) as f64, (rng.below(30) + 1) as f64, c)
                })
                .collect();
            let f = reduce(tuples.clone(), Mode::Pareto);
            crate::prop_assert!(f.is_valid(), "invariant violated");
            for t in &tuples {
                let dominated = f.tuples.iter().any(|ft| ft.dominates(t));
                crate::prop_assert!(
                    dominated,
                    "tuple ({},{},{}) not dominated",
                    t.mem,
                    t.time,
                    t.cost
                );
            }
            for (i, a) in f.tuples.iter().enumerate() {
                for (j, b) in f.tuples.iter().enumerate() {
                    if i != j {
                        crate::prop_assert!(!a.dominates(b), "frontier not minimal");
                    }
                }
            }
            Ok(())
        });
    }

    /// Property: product ⊗ is commutative in costs and reduce(product) of
    /// frontiers equals reduce over the raw cross-join.
    #[test]
    fn prop_product_equals_crossjoin() {
        ptest::quick("product-crossjoin", |rng: &mut XorShift| {
            let mk = |rng: &mut XorShift| -> Vec<Tuple> {
                (0..rng.range(1, 12))
                    .map(|_| tup((rng.below(20) + 1) as f64, (rng.below(20) + 1) as f64))
                    .collect()
            };
            let a = reduce(mk(rng), Mode::Pareto);
            let b = reduce(mk(rng), Mode::Pareto);
            let p1 = a.product(&b, Mode::Pareto);
            let p2 = b.product(&a, Mode::Pareto);
            crate::prop_assert!(p1.len() == p2.len(), "commutativity size");
            for (x, y) in p1.tuples.iter().zip(&p2.tuples) {
                crate::prop_assert!(
                    x.mem == y.mem && x.time == y.time && x.cost == y.cost,
                    "commutativity content"
                );
            }
            Ok(())
        });
    }

    /// Lemma 2 sanity: frontier of K random tuples has ~O(log K) size.
    #[test]
    fn expected_frontier_size_logarithmic() {
        let mut rng = XorShift::new(99);
        let k = 4096;
        let mut total = 0usize;
        let reps = 20;
        for _ in 0..reps {
            let tuples: Vec<Tuple> =
                (0..k).map(|_| tup(rng.f64(), rng.f64())).collect();
            total += reduce(tuples, Mode::Pareto).len();
        }
        let avg = total as f64 / reps as f64;
        let expect = (1..=k).map(|i| 1.0 / i as f64).sum::<f64>(); // H_K ≈ ln K
        assert!((avg - expect).abs() < 4.0, "avg {avg} vs H_K {expect}");
    }
}
