//! Struct-of-arrays frontier kernel.
//!
//! Every frontier operation in [`crate::frontier`] bottoms out here: the
//! three objectives live in three contiguous `f64` lanes ([`Lanes`]) and
//! the algorithms manipulate `u32` *positions* into those lanes instead of
//! moving boxed `Tuple`s (24 bytes of floats plus an `Arc` each) around.
//! That buys three things on the FT hot path:
//!
//! 1. **Linear-sweep dominance and ε-thinning.** The Algorithm-1 scan
//!    compares a candidate's (time, cost) against the *kept* set's
//!    pre-scaled `time·(1-ε)` / `cost·(1-ε)` lanes — two contiguous `f64`
//!    slices walked in lockstep, which the compiler auto-vectorizes —
//!    instead of a pointer-chasing rescan of boxed tuples.
//! 2. **Sort without payload traffic.** Ordering is established on a
//!    `u32` permutation; survivor tuples (and their `Arc` traces) are
//!    gathered once at the end, only for the positions that made the cut.
//! 3. **Divide-and-conquer merges.** A union of already-reduced frontiers
//!    is a merge of sorted runs, not a full re-sort: bottom-up pairwise
//!    stable merges reproduce the stable-sort permutation bit-for-bit
//!    (bottom-up mergesort *is* a stable sort) at merge cost.
//!
//! Bit-compatibility contract: every function here performs the same
//! floating-point comparisons and arithmetic, in the same order, as the
//! retired boxed engine preserved in `super::reference` — the differential
//! suite (`rust/tests/frontier_diff.rs`) holds the two bit-identical on
//! adversarial inputs (exact ties, ε-boundary points, ±0.0, subnormals,
//! the all-zero-cost 2-D degenerate case).

use super::{Mode, THIN_EPS};
use std::cmp::Ordering;

/// The three objective lanes of a tuple set, stored contiguously.
pub(crate) struct Lanes {
    /// Peak per-device memory, one entry per tuple.
    pub mem: Vec<f64>,
    /// Per-iteration time, one entry per tuple.
    pub time: Vec<f64>,
    /// Dollar cost, one entry per tuple.
    pub cost: Vec<f64>,
}

impl Lanes {
    /// Empty lanes with capacity for `n` tuples.
    pub fn with_capacity(n: usize) -> Self {
        Self {
            mem: Vec::with_capacity(n),
            time: Vec::with_capacity(n),
            cost: Vec::with_capacity(n),
        }
    }

    /// Append one tuple's objectives.
    #[inline]
    pub fn push(&mut self, mem: f64, time: f64, cost: f64) {
        self.mem.push(mem);
        self.time.push(time);
        self.cost.push(cost);
    }

    /// Number of tuples.
    pub fn len(&self) -> usize {
        self.mem.len()
    }

    /// Are there no tuples?
    pub fn is_empty(&self) -> bool {
        self.mem.is_empty()
    }

    /// Lexicographic (mem, time, cost) comparison of positions `a` and
    /// `b` — the frontier sort order. Panics on NaN, like the boxed
    /// engine did.
    #[inline]
    fn cmp(&self, a: u32, b: u32) -> Ordering {
        let (a, b) = (a as usize, b as usize);
        (self.mem[a], self.time[a], self.cost[a])
            .partial_cmp(&(self.mem[b], self.time[b], self.cost[b]))
            .unwrap()
    }

    /// First position (in `order`) minimizing `key` — ties keep the
    /// earliest, matching `Iterator::min_by`.
    fn argmin_by<K: PartialOrd>(&self, order: &[u32], key: impl Fn(usize) -> K) -> u32 {
        let mut best = order[0];
        for &p in &order[1..] {
            if key(p as usize).partial_cmp(&key(best as usize)).unwrap() == Ordering::Less {
                best = p;
            }
        }
        best
    }

    /// First position in `order` minimizing `(time, mem, cost)`.
    fn argmin_time(&self, order: &[u32]) -> u32 {
        self.argmin_by(order, |p| (self.time[p], self.mem[p], self.cost[p]))
    }

    /// First position in `order` minimizing `(cost, mem, time)`.
    fn argmin_cost(&self, order: &[u32]) -> u32 {
        self.argmin_by(order, |p| (self.cost[p], self.mem[p], self.time[p]))
    }

    /// First position in `order` minimizing `(mem, time, cost)`.
    fn argmin_mem(&self, order: &[u32]) -> u32 {
        self.argmin_by(order, |p| (self.mem[p], self.time[p], self.cost[p]))
    }

    /// Stable sort of all positions by (mem, time, cost).
    pub fn sorted_perm(&self) -> Vec<u32> {
        let mut perm: Vec<u32> = (0..self.len() as u32).collect();
        perm.sort_by(|&a, &b| self.cmp(a, b));
        perm
    }

    /// Is the run `lo..hi` already sorted by (mem, time, cost)?
    fn run_sorted(&self, lo: u32, hi: u32) -> bool {
        (lo..hi.saturating_sub(1)).all(|i| self.cmp(i, i + 1) != Ordering::Greater)
    }

    /// Permutation sorting the concatenation of `runs` (given as end
    /// offsets: run `r` spans `runs[r-1]..runs[r]`, with an implicit 0
    /// start). When every run is itself sorted this is a bottom-up
    /// divide-and-conquer stable merge — bit-identical to a stable sort
    /// of the concatenation, at merge cost. Falls back to a full stable
    /// sort when any run is unsorted (e.g. a singleton-product output
    /// whose uniform shift collapsed memory ties).
    pub fn merged_perm(&self, runs: &[u32]) -> Vec<u32> {
        let mut lo = 0u32;
        let mut sorted_runs: Vec<Vec<u32>> = Vec::with_capacity(runs.len());
        for &hi in runs {
            if !self.run_sorted(lo, hi) {
                return self.sorted_perm();
            }
            sorted_runs.push((lo..hi).collect());
            lo = hi;
        }
        // Bottom-up mergesort over the pre-sorted runs; merging adjacent
        // pairs left to right keeps concatenation order for ties, so the
        // result is the stable-sort permutation.
        while sorted_runs.len() > 1 {
            let mut next = Vec::with_capacity(sorted_runs.len().div_ceil(2));
            let mut it = sorted_runs.into_iter();
            while let Some(a) = it.next() {
                match it.next() {
                    Some(b) => next.push(self.merge_two(a, b)),
                    None => next.push(a),
                }
            }
            sorted_runs = next;
        }
        sorted_runs.pop().unwrap_or_default()
    }

    /// Stable two-way merge: positions from `a` win ties (they precede
    /// `b` in concatenation order).
    fn merge_two(&self, a: Vec<u32>, b: Vec<u32>) -> Vec<u32> {
        let mut out = Vec::with_capacity(a.len() + b.len());
        let (mut i, mut j) = (0, 0);
        while i < a.len() && j < b.len() {
            if self.cmp(a[i], b[j]) != Ordering::Greater {
                out.push(a[i]);
                i += 1;
            } else {
                out.push(b[j]);
                j += 1;
            }
        }
        out.extend_from_slice(&a[i..]);
        out.extend_from_slice(&b[j..]);
        out
    }

    /// Algorithm 1 + ε-thinning over a (mem, time, cost)-sorted
    /// permutation: the surviving positions in final frontier order.
    /// `perm` must sort the lanes (from [`Lanes::sorted_perm`] or
    /// [`Lanes::merged_perm`]); the single-objective mode truncations are
    /// handled by [`reduce_indices`], not here.
    fn thin_sorted(&self, perm: &[u32]) -> Vec<u32> {
        if perm.is_empty() {
            return Vec::new();
        }
        // remember the global min-time / min-cost positions (first minimal
        // in sorted order) so thinning can never lose the extremes.
        let best_time = self.argmin_time(perm);
        let best_cost = self.argmin_cost(perm);
        let mut out: Vec<u32> = Vec::new();
        // the kept set's ε-scaled lanes, contiguous so the dominance check
        // below is a linear sweep over two f64 slices.
        let mut kept_time_eps: Vec<f64> = Vec::new();
        let mut kept_cost_eps: Vec<f64> = Vec::new();
        for &p in perm {
            let (t, c) = (self.time[p as usize], self.cost[p as usize]);
            // every kept q has q.mem <= t.mem by the sort, so ε-dominance
            // only needs the time and cost conditions. With all costs
            // equal the cost condition is vacuous and this is the 2-D
            // staircase scan.
            let eps_dominated = kept_time_eps
                .iter()
                .zip(kept_cost_eps.iter())
                .any(|(&qt, &qc)| qt <= t && qc <= c);
            if !eps_dominated {
                out.push(p);
                kept_time_eps.push(t * (1.0 - THIN_EPS));
                kept_cost_eps.push(c * (1.0 - THIN_EPS));
            }
        }
        // re-attach the exact objective extremes if thinning dropped them
        // (the second check sees a just-re-attached best_time, in exactly
        // the boxed engine's order).
        let bt = best_time as usize;
        if out.iter().all(|&q| self.time[q as usize] > self.time[bt]) {
            out.push(best_time);
        }
        let bc = best_cost as usize;
        if out.iter().all(|&q| self.cost[q as usize] > self.cost[bc]) {
            out.push(best_cost);
        }
        out.sort_by(|&a, &b| self.cmp(a, b));
        // drop anything the re-attached extremes exactly dominate, so the
        // result is a minimal (mutually non-dominated) set.
        let n = out.len();
        let keep: Vec<bool> = (0..n)
            .map(|i| {
                !(0..n).any(|j| {
                    if i == j {
                        return false;
                    }
                    let (qi, qj) = (out[i] as usize, out[j] as usize);
                    let dom = self.mem[qj] <= self.mem[qi]
                        && self.time[qj] <= self.time[qi]
                        && self.cost[qj] <= self.cost[qi];
                    let tie = self.mem[qj] == self.mem[qi]
                        && self.time[qj] == self.time[qi]
                        && self.cost[qj] == self.cost[qi];
                    dom && (!tie || j < i)
                })
            })
            .collect();
        out.into_iter().zip(keep).filter_map(|(p, k)| if k { Some(p) } else { None }).collect()
    }
}

/// Full reduce over the lanes: surviving positions in final frontier
/// order. `runs`, when given, holds end offsets of already-sorted runs (a
/// union of reduced frontiers) so the sort becomes a divide-and-conquer
/// merge; `None` sorts from scratch. The single-objective modes pick the
/// first minimal position in *input* order, matching the boxed engine's
/// pre-sort `min_by`.
pub(crate) fn reduce_indices(lanes: &Lanes, mode: Mode, runs: Option<&[u32]>) -> Vec<u32> {
    if lanes.is_empty() {
        return Vec::new();
    }
    match mode {
        Mode::TimeOnly => {
            let order: Vec<u32> = (0..lanes.len() as u32).collect();
            return vec![lanes.argmin_time(&order)];
        }
        Mode::MemOnly => {
            let order: Vec<u32> = (0..lanes.len() as u32).collect();
            return vec![lanes.argmin_mem(&order)];
        }
        Mode::Pareto => {}
    }
    let perm = match runs {
        Some(r) => lanes.merged_perm(r),
        None => lanes.sorted_perm(),
    };
    lanes.thin_sorted(&perm)
}

/// Exact 3-D Pareto filter via a sort-based sweep: indices of the points
/// no other point dominates (duplicates keep the lowest index), ascending.
///
/// Replaces the quadratic all-pairs scan: after a stable lexicographic
/// sort a point can only be dominated by a *kept* point that sorts before
/// it (a dominator is lexicographically ≤ the dominated point, and a
/// killed dominator's own killer dominates transitively), so one forward
/// sweep against the kept set suffices — O(n log n + n·f) for frontier
/// size f instead of O(n²). Exact ties sort stably, so the lowest original
/// index is swept first and kills its duplicates, exactly like the
/// pairwise rule.
pub(crate) fn pareto_sweep(points: &[(f64, f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<u32> = (0..points.len() as u32).collect();
    idx.sort_by(|&a, &b| points[a as usize].partial_cmp(&points[b as usize]).unwrap());
    let mut kept: Vec<u32> = Vec::new();
    'outer: for &i in &idx {
        let p = points[i as usize];
        for &j in &kept {
            let q = points[j as usize];
            if q.0 <= p.0 && q.1 <= p.1 && q.2 <= p.2 {
                continue 'outer;
            }
        }
        kept.push(i);
    }
    kept.sort_unstable();
    kept.into_iter().map(|i| i as usize).collect()
}
