//! The pre-SoA frontier engine, frozen verbatim as a test oracle.
//!
//! This module is the boxed-tuple implementation the struct-of-arrays
//! engine (the private `soa` module) replaced. It is retained **exclusively for
//! tests and bench anchors**: the differential suite
//! (`rust/tests/frontier_diff.rs`) asserts the production operations in
//! [`crate::frontier`] stay *bit-identical* to these functions on seeded
//! random inputs (ties, ε-boundary points, ±0.0, subnormals), and
//! `bench_ft_large` times the two reduce kernels side by side so every
//! BENCH artifact carries the SoA speedup.
//!
//! Nothing outside tests/benches may call into here — the production call
//! graph goes through [`crate::frontier`] only. Keep this file in sync
//! with nothing: it is intentionally dead history, the executable spec the
//! rewrite was checked against.

use super::{Frontier, Mode, Trace, Tuple, THIN_EPS};

/// Oracle for [`crate::frontier::reduce`]: Algorithm 1 + ε-thinning via
/// the original sort-then-rescan over boxed tuples.
pub fn reduce(tuples: Vec<Tuple>, mode: Mode) -> Frontier {
    let combos: Vec<(f64, f64, f64, Tuple)> =
        tuples.into_iter().map(|t| (t.mem, t.time, t.cost, t)).collect();
    Frontier { tuples: reduce_by(combos, mode).into_iter().map(|(_, _, _, t)| t).collect() }
}

/// Oracle for [`Frontier::product`]: Cartesian combine over boxed tuples,
/// with the original singleton fast path and survivor-only trace
/// allocation.
pub fn product(a: &Frontier, b: &Frontier, mode: Mode) -> Frontier {
    if mode == Mode::Pareto && b.len() == 1 {
        let bt = &b.tuples[0];
        return Frontier { tuples: a.tuples.iter().map(|at| at.combine(bt)).collect() };
    }
    if mode == Mode::Pareto && a.len() == 1 {
        return product(b, a, mode);
    }
    let mut combos: Vec<(f64, f64, f64, (u32, u32))> = Vec::with_capacity(a.len() * b.len());
    for (i, at) in a.tuples.iter().enumerate() {
        for (j, bt) in b.tuples.iter().enumerate() {
            combos.push((
                at.mem + bt.mem,
                at.time + bt.time,
                at.cost + bt.cost,
                (i as u32, j as u32),
            ));
        }
    }
    let kept = reduce_by(combos, mode);
    Frontier {
        tuples: kept
            .into_iter()
            .map(|(mem, time, cost, (i, j))| {
                Tuple::with_cost(
                    mem,
                    time,
                    cost,
                    Trace::pair(&a.tuples[i as usize].trace, &b.tuples[j as usize].trace),
                )
            })
            .collect(),
    }
}

/// Oracle for [`Frontier::union`] (and, folded over parts in
/// concatenation order, for [`Frontier::union_many`]): concatenate, then
/// [`reduce`].
pub fn union(a: &Frontier, b: &Frontier, mode: Mode) -> Frontier {
    let mut out = Vec::with_capacity(a.len() + b.len());
    out.extend(a.tuples.iter().cloned());
    out.extend(b.tuples.iter().cloned());
    reduce(out, mode)
}

/// Oracle for [`crate::frontier::pareto_indices`]: the original exact
/// O(n²) pairwise scan (duplicates keep the lowest index).
pub fn pareto_indices(points: &[(f64, f64, f64)]) -> Vec<usize> {
    let dominates =
        |a: &(f64, f64, f64), b: &(f64, f64, f64)| a.0 <= b.0 && a.1 <= b.1 && a.2 <= b.2;
    let mut kept = Vec::new();
    'outer: for (i, p) in points.iter().enumerate() {
        for (j, q) in points.iter().enumerate() {
            if i == j || !dominates(q, p) {
                continue;
            }
            // strict domination kills p; an exact tie keeps the lowest index.
            if q != p || j < i {
                continue 'outer;
            }
        }
        kept.push(i);
    }
    kept
}

/// Oracle for [`Frontier::min_time`].
pub fn min_time(f: &Frontier) -> Option<&Tuple> {
    f.tuples
        .iter()
        .min_by(|a, b| (a.time, a.mem, a.cost).partial_cmp(&(b.time, b.mem, b.cost)).unwrap())
}

/// Oracle for [`Frontier::min_cost`].
pub fn min_cost(f: &Frontier) -> Option<&Tuple> {
    f.tuples
        .iter()
        .min_by(|a, b| (a.cost, a.mem, a.time).partial_cmp(&(b.cost, b.mem, b.time)).unwrap())
}

/// Oracle for [`Frontier::min_time_within`].
pub fn min_time_within(f: &Frontier, mem_budget: f64) -> Option<&Tuple> {
    f.tuples
        .iter()
        .filter(|t| t.mem <= mem_budget)
        .min_by(|a, b| (a.time, a.mem, a.cost).partial_cmp(&(b.time, b.mem, b.cost)).unwrap())
}

/// Oracle for [`Frontier::min_cost_within`].
pub fn min_cost_within(f: &Frontier, mem_budget: f64, deadline: f64) -> Option<&Tuple> {
    f.tuples
        .iter()
        .filter(|t| t.mem <= mem_budget && t.time <= deadline)
        .min_by(|a, b| (a.cost, a.time, a.mem).partial_cmp(&(b.cost, b.time, b.mem)).unwrap())
}

/// Oracle for [`Frontier::min_time_within_cost`].
pub fn min_time_within_cost(f: &Frontier, mem_budget: f64, budget_usd: f64) -> Option<&Tuple> {
    f.tuples
        .iter()
        .filter(|t| t.mem <= mem_budget && t.cost <= budget_usd)
        .min_by(|a, b| (a.time, a.cost, a.mem).partial_cmp(&(b.time, b.cost, b.mem)).unwrap())
}

/// Algorithm 1 over (mem, time, cost, payload) entries — the original
/// shared core of [`reduce`] and [`product`].
fn reduce_by<T: Clone>(mut items: Vec<(f64, f64, f64, T)>, mode: Mode) -> Vec<(f64, f64, f64, T)> {
    if items.is_empty() {
        return items;
    }
    match mode {
        Mode::TimeOnly => {
            let best = items
                .into_iter()
                .min_by(|a, b| (a.1, a.0, a.2).partial_cmp(&(b.1, b.0, b.2)).unwrap())
                .unwrap();
            return vec![best];
        }
        Mode::MemOnly => {
            let best = items
                .into_iter()
                .min_by(|a, b| (a.0, a.1, a.2).partial_cmp(&(b.0, b.1, b.2)).unwrap())
                .unwrap();
            return vec![best];
        }
        Mode::Pareto => {}
    }
    // Algorithm 1: ascending memory (time, then cost, as tiebreaks).
    items.sort_by(|a, b| (a.0, a.1, a.2).partial_cmp(&(b.0, b.1, b.2)).unwrap());
    // remember the global min-time / min-cost items so thinning can never
    // lose the objective extremes.
    let best_time = items
        .iter()
        .min_by(|a, b| (a.1, a.0, a.2).partial_cmp(&(b.1, b.0, b.2)).unwrap())
        .cloned()
        .unwrap();
    let best_cost = items
        .iter()
        .min_by(|a, b| (a.2, a.0, a.1).partial_cmp(&(b.2, b.0, b.1)).unwrap())
        .cloned()
        .unwrap();
    let mut out: Vec<(f64, f64, f64, T)> = Vec::new();
    for t in items {
        // every kept q has q.mem <= t.mem by the sort, so ε-dominance only
        // needs the time and cost conditions. With all costs equal the
        // cost condition is vacuous and this is the 2-D staircase scan.
        let eps_dominated = out
            .iter()
            .any(|q| q.1 * (1.0 - THIN_EPS) <= t.1 && q.2 * (1.0 - THIN_EPS) <= t.2);
        if !eps_dominated {
            out.push(t);
        }
    }
    // re-attach the exact objective extremes if thinning dropped them.
    if out.iter().all(|q| q.1 > best_time.1) {
        out.push(best_time);
    }
    if out.iter().all(|q| q.2 > best_cost.2) {
        out.push(best_cost);
    }
    out.sort_by(|a, b| (a.0, a.1, a.2).partial_cmp(&(b.0, b.1, b.2)).unwrap());
    // drop anything the re-attached extremes exactly dominate, so the
    // result is a minimal (mutually non-dominated) set.
    let n = out.len();
    let keep: Vec<bool> = (0..n)
        .map(|i| {
            !(0..n).any(|j| {
                if i == j {
                    return false;
                }
                let (qi, qj) = (&out[i], &out[j]);
                let dom = qj.0 <= qi.0 && qj.1 <= qi.1 && qj.2 <= qi.2;
                let tie = qj.0 == qi.0 && qj.1 == qi.1 && qj.2 == qi.2;
                dom && (!tie || j < i)
            })
        })
        .collect();
    out.into_iter().zip(keep).filter_map(|(t, k)| if k { Some(t) } else { None }).collect()
}
