//! Pricing layer: convert (time, cluster) into dollars.
//!
//! The paper's §1 motivation is a cloud user who wants to "improve the
//! efficiency or reduce the cost" of training; this module is where cost
//! stops being a metaphor and becomes money. Raw on-demand $/GPU-hour
//! rates live on [`DeviceSpec`](crate::cluster::DeviceSpec) (so mixed
//! clusters price each machine at its own generation's rate); this module
//! owns the *billing model* (on-demand vs spot), the time-to-dollars
//! conversions, and the dollar cost of elastic rescales — the pieces the
//! frontier search, the provisioning experiment and the scheduler all
//! share.

use crate::cluster::Cluster;

/// Spot-market discount relative to on-demand list price (~68% off, the
/// long-run average for GPU instances; interruptions are out of scope —
/// the simulator treats spot capacity as stable).
pub const SPOT_MULTIPLIER: f64 = 0.32;

/// How rented capacity is billed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Billing {
    /// On-demand list price.
    #[default]
    OnDemand,
    /// Spot / preemptible price ([`SPOT_MULTIPLIER`] x on-demand).
    Spot,
}

impl Billing {
    /// Multiplier applied to on-demand list rates.
    pub fn multiplier(self) -> f64 {
        match self {
            Billing::OnDemand => 1.0,
            Billing::Spot => SPOT_MULTIPLIER,
        }
    }

    /// CLI label.
    pub fn name(self) -> &'static str {
        match self {
            Billing::OnDemand => "on-demand",
            Billing::Spot => "spot",
        }
    }

    /// Parse a CLI flag value (`ondemand` / `on-demand` / `spot`).
    pub fn parse(s: &str) -> Option<Billing> {
        match s {
            "ondemand" | "on-demand" | "od" => Some(Billing::OnDemand),
            "spot" => Some(Billing::Spot),
            _ => None,
        }
    }
}

/// Rental rate of `cluster` in $/hour under `billing`.
pub fn usd_hour(cluster: &Cluster, billing: Billing) -> f64 {
    cluster.usd_hour() * billing.multiplier()
}

/// Rental rate of `cluster` in $/second under `billing`.
pub fn usd_per_sec(cluster: &Cluster, billing: Billing) -> f64 {
    usd_hour(cluster, billing) / 3600.0
}

/// Dollars to hold `cluster` for `time_s` seconds under `billing` — the
/// core (time, cluster) -> $ conversion. Billing is wall-clock: devices
/// cost money whether they compute or idle, which is exactly why slower-
/// but-smaller points on a frontier can be the cheaper ones.
pub fn usd(time_s: f64, cluster: &Cluster, billing: Billing) -> f64 {
    time_s * usd_per_sec(cluster, billing)
}

/// Dollars burned by an elastic rescale: the job makes no progress for
/// `downtime_s` (checkpoint, strategy re-search, re-shard, restart — see
/// [`crate::sched::RescaleModel`]) while the devices keep billing. Charged
/// at the *new* allocation's cluster rate, since that is what is being
/// held during the move.
pub fn rescale_usd(downtime_s: f64, cluster: &Cluster, billing: Billing) -> f64 {
    usd(downtime_s, cluster, billing)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_are_consistent() {
        let c = Cluster::with_gpus(4); // 4 x V100 at $3.06
        let rate = usd_hour(&c, Billing::OnDemand);
        assert!((rate - 4.0 * 3.06).abs() < 1e-9);
        assert!((usd_per_sec(&c, Billing::OnDemand) - rate / 3600.0).abs() < 1e-12);
        // one hour at the hourly rate costs the hourly rate.
        assert!((usd(3600.0, &c, Billing::OnDemand) - rate).abs() < 1e-9);
        assert_eq!(usd(0.0, &c, Billing::OnDemand), 0.0);
    }

    #[test]
    fn spot_is_cheaper_by_the_documented_multiplier() {
        let c = Cluster::mixed_generation();
        let od = usd_hour(&c, Billing::OnDemand);
        let spot = usd_hour(&c, Billing::Spot);
        assert!((spot - od * SPOT_MULTIPLIER).abs() < 1e-9);
        assert!(spot < od);
    }

    #[test]
    fn rescale_dollars_scale_with_downtime() {
        let c = Cluster::with_gpus(8);
        let a = rescale_usd(10.0, &c, Billing::OnDemand);
        let b = rescale_usd(20.0, &c, Billing::OnDemand);
        assert!((b - 2.0 * a).abs() < 1e-9);
        assert!(a > 0.0);
    }

    #[test]
    fn billing_parse_roundtrip() {
        assert_eq!(Billing::parse("spot"), Some(Billing::Spot));
        assert_eq!(Billing::parse("ondemand"), Some(Billing::OnDemand));
        assert_eq!(Billing::parse("on-demand"), Some(Billing::OnDemand));
        assert_eq!(Billing::parse("free"), None);
        assert_eq!(Billing::default(), Billing::OnDemand);
    }
}
