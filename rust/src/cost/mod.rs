//! Execution-cost models: operator/edge costs (Eq. 1-2), whole-strategy
//! evaluation (Eq. 3), and the three communication-time oracles of §3.2.

pub mod comm;
pub mod estimator;
pub mod op_cost;

pub use comm::{CommModel, GroundTruthComm, NaiveComm};
pub use estimator::{eval_strategy, ReuseChoice, StrategyCost};
pub use op_cost::{edge_costs, mesh_dim_crosses, op_cost, OpCost};
