//! Execution-cost models: operator/edge costs (Eq. 1-2), whole-strategy
//! evaluation (Eq. 3), the three communication-time oracles of §3.2, and
//! the pricing layer converting (time, cluster) into dollars.

pub mod comm;
pub mod estimator;
pub mod op_cost;
pub mod pricing;

pub use comm::{CommModel, GroundTruthComm, NaiveComm};
pub use estimator::{eval_strategy, ReuseChoice, StrategyCost};
pub use op_cost::{edge_costs, mesh_dim_crosses, op_cost, OpCost};
pub use pricing::Billing;
