//! Communication-time models (§3.2 "Improving cost estimation accuracy").
//!
//! Three oracles implement [`CollectiveCost`]:
//!
//! - [`GroundTruthComm`] — the α–β ring-collective model over the cluster
//!   topology with NIC contention between concurrent groups. This is what
//!   the discrete-event simulator charges (plus scheduling overheads), i.e.
//!   our stand-in for "actually running it on the testbed".
//! - [`CommModel`] — the paper's estimator: offline "profiles" the actual
//!   bandwidth at payload sizes `2^i` per device-partitioning scheme
//!   (group size x machine-crossing), then predicts by interpolating the
//!   bandwidths of the surrounding powers of two. Matches the paper's
//!   6–7 % estimation-error regime.
//! - [`NaiveComm`] — the OptCNN/FlexFlow baseline the paper criticizes:
//!   `bytes / nominal-bandwidth`, no latency, no contention (Table 2's
//!   70 %+ error comparison).

use crate::cluster::Cluster;
use crate::parallel::resched::{Coll, CollectiveCost};

/// Payload-volume factor of a ring collective: how many times the payload
/// crosses a link, per participant.
fn volume_factor(coll: Coll, g: u32) -> f64 {
    let g = g as f64;
    match coll {
        Coll::AllReduce => 2.0 * (g - 1.0) / g,
        Coll::AllGather => g - 1.0, // payload = per-device input shard
        Coll::ReduceScatter => (g - 1.0) / g,
        Coll::AllToAll => (g - 1.0) / g,
        Coll::Broadcast => 1.0,
    }
}

/// Latency steps of a ring collective.
fn latency_steps(coll: Coll, g: u32) -> f64 {
    match coll {
        Coll::AllReduce => 2.0 * (g as f64 - 1.0),
        _ => g as f64 - 1.0,
    }
}

/// α–β ground truth with NIC contention.
#[derive(Debug, Clone)]
pub struct GroundTruthComm {
    /// The device graph whose links are being priced.
    pub cluster: Cluster,
}

impl GroundTruthComm {
    /// Oracle for `cluster`.
    pub fn new(cluster: Cluster) -> Self {
        Self { cluster }
    }

    /// Effective per-flow bandwidth for a group of size `g`.
    ///
    /// Intra-machine (NVLink/PCIe switch): full link bandwidth per group.
    /// Crossing machines: the per-machine NIC is shared by all concurrent
    /// groups whose ring crosses it — with `D/g` groups running the same
    /// collective layer-wide, each machine's NIC multiplexes
    /// `max(1, groups/machines)` flows (the paper's "different groups may
    /// still contend for bandwidth").
    /// Crossing rings are routed machine-major over the allocation's
    /// machines, so the bandwidth is the slowest pairwise link on that
    /// route ([`Cluster::inter_link`] is the ring bottleneck) — on an
    /// asymmetric fabric one straggler NIC paces every crossing
    /// collective, which a single global `inter` preset cannot express.
    pub fn effective_bw(&self, g: u32, crossing: bool) -> f64 {
        if !crossing {
            self.cluster.intra_link().bandwidth
        } else {
            let d = self.cluster.n_devices() as u32;
            let groups = (d / g.max(1)).max(1);
            let contention = (groups as f64 / self.cluster.n_machines() as f64).max(1.0);
            self.cluster.inter_link().bandwidth / contention
        }
    }

    fn latency(&self, crossing: bool) -> f64 {
        if crossing {
            self.cluster.inter_link().latency
        } else {
            self.cluster.intra_link().latency
        }
    }
}

impl CollectiveCost for GroundTruthComm {
    fn coll_time(&self, coll: Coll, bytes: f64, group: u32, crossing: bool) -> f64 {
        if group <= 1 || bytes <= 0.0 {
            return 0.0;
        }
        let bw = self.effective_bw(group, crossing);
        volume_factor(coll, group) * bytes / bw + latency_steps(coll, group) * self.latency(crossing)
    }

    fn group_crosses(&self, group: u32) -> bool {
        self.cluster.tiling_crosses(group as usize)
    }
}

/// Profile-based estimator: measured bandwidth at payload sizes `2^i`
/// per (group size, crossing) partitioning scheme, interpolated between
/// the surrounding powers of two (§3.2).
#[derive(Debug, Clone)]
pub struct CommModel {
    cluster: Cluster,
    /// profiles[(g, crossing)] -> measured bandwidth at bytes = 2^i,
    /// i in 0..P.
    profiles: std::collections::HashMap<(u32, bool), Vec<f64>>,
    max_exp: usize,
}

impl CommModel {
    /// "Profile" the cluster by measuring the ground-truth all-reduce
    /// bandwidth at every power-of-two payload for every divisor group
    /// size. In a real deployment these are microbenchmarks; here the
    /// ground truth *is* the α–β model (the simulator additionally charges
    /// scheduling overheads the profile cannot see — the source of the
    /// paper's consistent underestimation).
    pub fn profile(cluster: &Cluster) -> Self {
        let gt = GroundTruthComm::new(cluster.clone());
        let d = cluster.n_devices() as u32;
        let max_exp = 36; // up to 64 GB payloads
        let mut profiles = std::collections::HashMap::new();
        for g in 2..=d {
            if d % g != 0 {
                continue;
            }
            for crossing in [false, true] {
                let mut bws = Vec::with_capacity(max_exp + 1);
                for i in 0..=max_exp {
                    let bytes = (1u64 << i) as f64;
                    // measured bandwidth = payload volume / time, using
                    // all-reduce as the probe collective (the paper
                    // profiles each collective pattern; ring collectives
                    // share the same effective link bandwidth).
                    let t = gt.coll_time(Coll::AllReduce, bytes, g, crossing);
                    let vol = volume_factor(Coll::AllReduce, g) * bytes;
                    bws.push(vol / t);
                }
                profiles.insert((g, crossing), bws);
            }
        }
        Self { cluster: cluster.clone(), profiles, max_exp }
    }

    /// Interpolated effective bandwidth for a payload of `bytes`.
    fn interp_bw(&self, g: u32, crossing: bool, bytes: f64) -> f64 {
        let key = (g, crossing);
        let Some(bws) = self.profiles.get(&key) else {
            // non-divisor group (can appear transiently in re-scheduling
            // search): fall back to the nearest profiled divisor.
            let mut best: Option<(u32, &Vec<f64>)> = None;
            for ((pg, pc), v) in &self.profiles {
                if *pc == crossing {
                    let better = match best {
                        None => true,
                        Some((bg, _)) => {
                            (*pg as i64 - g as i64).abs() < (bg as i64 - g as i64).abs()
                        }
                    };
                    if better {
                        best = Some((*pg, v));
                    }
                }
            }
            return best.map(|(_, v)| interp_in(v, bytes, self.max_exp)).unwrap_or(1e9);
        };
        interp_in(bws, bytes, self.max_exp)
    }
}

/// Interpolate bandwidth between the two surrounding powers of two.
fn interp_in(bws: &[f64], bytes: f64, max_exp: usize) -> f64 {
    if bytes <= 1.0 {
        return bws[0];
    }
    let l2 = bytes.log2();
    let i = (l2.floor() as usize).min(max_exp);
    let j = (i + 1).min(max_exp);
    let frac = (l2 - i as f64).clamp(0.0, 1.0);
    bws[i] * (1.0 - frac) + bws[j] * frac
}

impl CollectiveCost for CommModel {
    fn coll_time(&self, coll: Coll, bytes: f64, group: u32, crossing: bool) -> f64 {
        if group <= 1 || bytes <= 0.0 {
            return 0.0;
        }
        let vol = volume_factor(coll, group) * bytes;
        // latency is visible in the profiled bandwidth curve (small sizes
        // have low measured bandwidth), so prediction is volume / bw only.
        vol / self.interp_bw(group, crossing, bytes)
    }

    fn group_crosses(&self, group: u32) -> bool {
        self.cluster.tiling_crosses(group as usize)
    }
}

/// The naive estimator the paper measures 70 %+ error for: payload over
/// nominal link bandwidth, ignoring latency and contention.
#[derive(Debug, Clone)]
pub struct NaiveComm {
    /// The device graph whose links are being priced.
    pub cluster: Cluster,
}

impl CollectiveCost for NaiveComm {
    fn coll_time(&self, coll: Coll, bytes: f64, group: u32, crossing: bool) -> f64 {
        if group <= 1 || bytes <= 0.0 {
            return 0.0;
        }
        let bw = if crossing {
            self.cluster.inter_link().bandwidth
        } else {
            self.cluster.intra_link().bandwidth
        };
        volume_factor(coll, group) * bytes / bw
    }

    fn group_crosses(&self, group: u32) -> bool {
        self.cluster.tiling_crosses(group as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    fn gt() -> GroundTruthComm {
        GroundTruthComm::new(Cluster::paper_testbed())
    }

    #[test]
    fn crossing_slower_than_intra() {
        let g = gt();
        let a = g.coll_time(Coll::AllReduce, 1e8, 8, false);
        let b = g.coll_time(Coll::AllReduce, 1e8, 8, true);
        assert!(b > 5.0 * a, "inter {b} vs intra {a}");
    }

    #[test]
    fn latency_dominates_small_transfers() {
        let g = gt();
        let t_small = g.coll_time(Coll::AllReduce, 1024.0, 16, true);
        let pure_bw = 2.0 * 15.0 / 16.0 * 1024.0 / g.effective_bw(16, true);
        assert!(t_small > 10.0 * pure_bw, "latency term must dominate");
    }

    #[test]
    fn profile_interpolation_accurate() {
        // The estimator should be within a few % of ground truth at
        // arbitrary (non-power-of-two) sizes.
        let cluster = Cluster::paper_testbed();
        let model = CommModel::profile(&cluster);
        let truth = gt();
        for &bytes in &[3000.0, 1.5e6, 7.7e7, 9.9e8] {
            for &g in &[2u32, 4, 8, 16] {
                for crossing in [false, true] {
                    let est = model.coll_time(Coll::AllReduce, bytes, g, crossing);
                    let act = truth.coll_time(Coll::AllReduce, bytes, g, crossing);
                    let err = (est - act).abs() / act;
                    // small payloads sit on the steep (latency-dominated)
                    // part of the bandwidth curve where log2-interpolation
                    // is least accurate — the paper reports 6-7% overall.
                    assert!(err < 0.08, "err {err} at bytes={bytes} g={g} crossing={crossing}");
                }
            }
        }
    }

    #[test]
    fn naive_underestimates_badly_on_small_payloads() {
        let cluster = Cluster::paper_testbed();
        let naive = NaiveComm { cluster };
        let truth = gt();
        let est = naive.coll_time(Coll::AllReduce, 64.0 * 1024.0, 16, true);
        let act = truth.coll_time(Coll::AllReduce, 64.0 * 1024.0, 16, true);
        let err = (act - est) / act;
        assert!(err > 0.5, "naive err {err} should be large (paper: ~70%)");
    }

    #[test]
    fn contention_reduces_bandwidth() {
        let g = gt();
        // 8 groups of 2 crossing machines contend harder than 1 group of 16.
        assert!(g.effective_bw(2, true) < g.effective_bw(16, true));
    }

    #[test]
    fn straggler_link_paces_crossing_collectives() {
        // 16-device prefix of the straggler testbed stays on 4x RDMA; the
        // full 24 devices route the ring over the RDMA-less NIC.
        let full = Cluster::straggler_link();
        let fast = GroundTruthComm::new(full.sub_cluster(16));
        let slow = GroundTruthComm::new(full);
        let a = fast.coll_time(Coll::AllReduce, 1e8, 8, true);
        let b = slow.coll_time(Coll::AllReduce, 1e8, 8, true);
        assert!(b > 4.0 * a, "straggler ring {b} vs fast ring {a}");
    }

    #[test]
    fn zero_cases() {
        let g = gt();
        assert_eq!(g.coll_time(Coll::AllReduce, 1e6, 1, false), 0.0);
        assert_eq!(g.coll_time(Coll::AllGather, 0.0, 8, false), 0.0);
    }
}
