//! Operator and edge execution costs (Eq. 1 and Eq. 2).
//!
//! - `m(o, s) = m_p + m_t`: per-device parameter memory (param + gradient;
//!   plain SGD, matching the executor) plus stashed-activation memory.
//! - `t(o, s) = t_c + t_s`: compute time (FLOP-rate bound with a
//!   memory-bandwidth floor and a launch overhead) plus synchronization
//!   time (gradient all-reduce over every mesh dim the parameter is
//!   replicated across).
//! - `t(e, s_i, s_j)`: tensor re-scheduling cost between the producer's
//!   output split and the consumer's required split (shortest collective
//!   path, Figure 5), with the three tensor-reuse options of §4.2 turning
//!   each edge into a small (memory, time) frontier.

use crate::cluster::Cluster;
use crate::graph::{Edge, Graph, Op};
use crate::parallel::resched::{reschedule_cost, Coll, CollectiveCost};
use crate::parallel::{edge_cost_options, ParallelConfig};

/// Per-operator kernel-launch overhead (seconds). Part of why many small
/// ops cost more than one fused op; also keeps t_c strictly positive.
pub const LAUNCH_OVERHEAD: f64 = 10e-6;

/// Decomposed operator cost.
#[derive(Debug, Clone, Copy, Default)]
pub struct OpCost {
    /// Peak per-device bytes (params + grads + stashed activations).
    pub mem: f64,
    /// t_c: forward+backward compute.
    pub t_compute: f64,
    /// t_s: parameter-gradient synchronization.
    pub t_sync: f64,
}

impl OpCost {
    /// Total operator time `t_c + t_s` (Eq. 1).
    pub fn time(&self) -> f64 {
        self.t_compute + self.t_sync
    }
}

/// Does any group along mesh dim `m` of `cfg` cross machines? Exact under
/// the machine-major row-major placement rule: device ids within a group
/// are increasing, so a group crosses iff its first and last members sit
/// on different machines; every group origin is checked, which matters on
/// clusters with a partial last machine where small groups can straddle
/// the boundary.
pub fn mesh_dim_crosses(cfg: &ParallelConfig, m: usize, cluster: &Cluster) -> bool {
    if cluster.n_machines() <= 1 {
        return false;
    }
    // Group origins occupy [k*period, k*period + stride) and the group at
    // origin `o` covers device ids [o, o + span_end]. The boundary between
    // devices b-1 and b is straddled iff some origin lies in
    // [b - span_end, b) — an O(n_machines) check with no allocation (this
    // sits in op_cost, the FT search's innermost cost evaluation).
    let stride = cfg.mesh.stride(m) as usize;
    let size = cfg.mesh.dims[m] as usize;
    let period = stride * size;
    let span_end = period - stride;
    let total = cfg.mesh.n_devices() as usize;
    let mut b = 0usize;
    for mach in &cluster.machines {
        b += mach.gpus;
        if b >= total {
            break;
        }
        let lo = b.saturating_sub(span_end);
        let origin = if lo % period < stride { lo } else { (lo / period + 1) * period };
        if origin < b {
            return true;
        }
    }
    false
}

/// Eq. 1: cost of operator `op` under configuration `cfg`.
pub fn op_cost(
    op: &Op,
    cfg: &ParallelConfig,
    cluster: &Cluster,
    comm: &dyn CollectiveCost,
) -> OpCost {
    // A synchronous step advances at the slowest participating device
    // (the mesh occupies the first n_devices of the machine-major
    // numbering), so mixed-generation sets are charged the bottleneck
    // FLOP rate and memory bandwidth.
    let dev = cluster.bottleneck_device(cfg.n_devices() as usize);
    let par = cfg.compute_parallelism() as f64;

    // ---- t_c: fwd + bwd ≈ 3x fwd FLOPs, divided over the compute shards,
    // with a memory-bandwidth floor for bandwidth-bound ops.
    let flops = 3.0 * op.flops_fwd / par;
    let param_shard = op.param_bytes() / cfg.param_shards(op) as f64;
    let out_shard = op.out.bytes() / cfg.out_split(op).n_shards() as f64;
    let bytes_touched = 3.0 * (param_shard + out_shard);
    let t_compute =
        (flops / dev.flops).max(bytes_touched / dev.mem_bw) + LAUNCH_OVERHEAD;

    // ---- t_s: gradient all-reduce over every mesh dim that replicates
    // the parameter (Batch/Spatial-assigned dims).
    let mut t_sync = 0.0;
    for (m, g) in cfg.grad_sync_mesh_dims(op) {
        let crossing = mesh_dim_crosses(cfg, m, cluster);
        t_sync += comm.coll_time(Coll::AllReduce, param_shard, g, crossing);
    }

    // ---- m: parameter (+ gradient; plain SGD) + stashed activations.
    let mem = 2.0 * param_shard + op.out.bytes() / cfg.out_split(op).n_shards() as f64
        * op.act_keep_factor;

    OpCost { mem, t_compute, t_sync }
}

/// Edge cost options (Eq. 2 + §4.2 tensor reuse): each entry is
/// (extra_memory, time) for one reuse policy; entry 0 is always the
/// cheapest-memory option. The forward re-schedule appears in all options;
/// `KeepBoth` pays memory to avoid the backward re-materialization.
pub fn edge_costs(
    g: &Graph,
    e: &Edge,
    src_cfg: &ParallelConfig,
    dst_cfg: &ParallelConfig,
    comm: &dyn CollectiveCost,
) -> Vec<(f64, f64)> {
    let src_op = g.op(e.src);
    let dst_op = g.op(e.dst);
    let tensor = &src_op.out;
    let from = src_cfg.out_split(src_op);
    let to = dst_cfg.required_input_split(dst_op, tensor);
    if from == to {
        return vec![(0.0, 0.0)];
    }
    let dims: Vec<i64> = tensor.dims.iter().map(|d| d.size).collect();
    let t = reschedule_cost(tensor.bytes(), &dims, &from, &to, comm);
    if !t.is_finite() {
        // unreachable layout (should not happen): prohibitively expensive.
        return vec![(f64::INFINITY, f64::INFINITY)];
    }
    if t == 0.0 {
        // free transformation (e.g. slicing a replicated tensor).
        return vec![(0.0, 0.0)];
    }
    let copy_bytes = to.bytes_per_device(tensor.bytes());
    edge_cost_options(true, copy_bytes, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::comm::GroundTruthComm;
    use crate::graph::models::tiny_mlp;
    use crate::parallel::enumerate_configs;

    fn setup() -> (crate::graph::Graph, Cluster, GroundTruthComm) {
        let cluster = Cluster::paper_testbed();
        let comm = GroundTruthComm::new(cluster.clone());
        (tiny_mlp(256), cluster, comm)
    }

    #[test]
    fn dp_pays_grad_sync_mp_does_not() {
        let (g, cluster, comm) = setup();
        let fc1 = g.ops.iter().find(|o| o.name == "fc1").unwrap();
        let cfgs = enumerate_configs(fc1, 4, 2);
        let b = fc1.batch_axis().unwrap();
        let dp = cfgs.iter().find(|c| c.axis_shards(b) == 4).unwrap();
        let mp = cfgs.iter().find(|c| c.axis_shards(1) == 4).unwrap();
        let dp_cost = op_cost(fc1, dp, &cluster, &comm);
        let mp_cost = op_cost(fc1, mp, &cluster, &comm);
        assert!(dp_cost.t_sync > 0.0);
        assert_eq!(mp_cost.t_sync, 0.0);
        // model parallelism shards the parameter memory 4x.
        assert!(mp_cost.mem < dp_cost.mem);
    }

    #[test]
    fn replication_increases_memory_and_compute() {
        let (g, cluster, comm) = setup();
        let fc1 = g.ops.iter().find(|o| o.name == "fc1").unwrap();
        let cfgs = enumerate_configs(fc1, 4, 2);
        let b = fc1.batch_axis().unwrap();
        let dp = cfgs.iter().find(|c| c.axis_shards(b) == 4).unwrap();
        let rep = cfgs.iter().find(|c| c.replication() == 4).unwrap();
        let dp_cost = op_cost(fc1, dp, &cluster, &comm);
        let rep_cost = op_cost(fc1, rep, &cluster, &comm);
        assert!(rep_cost.t_compute > dp_cost.t_compute);
        assert!(rep_cost.mem > dp_cost.mem);
        // ...but replication needs no sync at all.
        assert_eq!(rep_cost.t_sync, 0.0);
    }

    #[test]
    fn matching_splits_zero_edge_cost() {
        let (g, cluster, comm) = setup();
        let _ = cluster;
        let fc1 = g.ops.iter().find(|o| o.name == "fc1").unwrap();
        let relu1 = g.ops.iter().find(|o| o.name == "relu1").unwrap();
        let e = g.edges.iter().find(|e| e.src == fc1.id && e.dst == relu1.id).unwrap();
        let c_src = ParallelConfig::data_parallel(fc1, 4).unwrap();
        let c_dst = ParallelConfig::data_parallel(relu1, 4).unwrap();
        assert_eq!(edge_costs(&g, e, &c_src, &c_dst, &comm), vec![(0.0, 0.0)]);
    }

    #[test]
    fn mismatched_splits_offer_reuse_tradeoff() {
        let (g, cluster, comm) = setup();
        let _ = cluster;
        let fc1 = g.ops.iter().find(|o| o.name == "fc1").unwrap();
        let relu1 = g.ops.iter().find(|o| o.name == "relu1").unwrap();
        let e = g.edges.iter().find(|e| e.src == fc1.id && e.dst == relu1.id).unwrap();
        // producer splits batch; consumer needs feature split.
        let c_src = ParallelConfig::data_parallel(fc1, 4).unwrap();
        let cfgs = enumerate_configs(relu1, 4, 2);
        let feat = relu1.axes.iter().position(|a| a.name == "fc1_out").unwrap();
        let c_dst = cfgs.iter().find(|c| c.axis_shards(feat) == 4).unwrap();
        let opts = edge_costs(&g, e, &c_src, c_dst, &comm);
        assert!(opts.len() >= 2, "expect reuse trade-off, got {opts:?}");
        // one option trades memory for time:
        assert!(opts.iter().any(|&(m, _)| m > 0.0));
        assert!(opts.iter().any(|&(m, _)| m == 0.0));
    }

    #[test]
    fn bottleneck_device_governs_mixed_cluster_compute() {
        use crate::cluster::{DeviceSpec, LinkKind, Machine};
        let g = tiny_mlp(256);
        let fc1 = g.ops.iter().find(|o| o.name == "fc1").unwrap();
        let cfg = ParallelConfig::data_parallel(fc1, 4).unwrap();
        let mk = |machines: Vec<Machine>, name: &str| {
            Cluster::from_machines(name, machines, LinkKind::IbRdma)
        };
        let all_v = mk(
            vec![
                Machine::new(DeviceSpec::v100(), 2, LinkKind::NvLink),
                Machine::new(DeviceSpec::v100(), 2, LinkKind::NvLink),
            ],
            "2x2xV100",
        );
        let all_a = mk(
            vec![
                Machine::new(DeviceSpec::a100(), 2, LinkKind::NvLink),
                Machine::new(DeviceSpec::a100(), 2, LinkKind::NvLink),
            ],
            "2x2xA100",
        );
        let mixed = mk(
            vec![
                Machine::new(DeviceSpec::a100(), 2, LinkKind::NvLink),
                Machine::new(DeviceSpec::v100(), 2, LinkKind::NvLink),
            ],
            "2xA100+2xV100",
        );
        let c_v = op_cost(fc1, &cfg, &all_v, &GroundTruthComm::new(all_v.clone()));
        let c_a = op_cost(fc1, &cfg, &all_a, &GroundTruthComm::new(all_a.clone()));
        let c_m = op_cost(fc1, &cfg, &mixed, &GroundTruthComm::new(mixed.clone()));
        assert!(c_a.t_compute < c_v.t_compute, "A100s must be faster");
        // the V100 in the set drags the mixed cluster to V100 pace.
        assert_eq!(c_m.t_compute, c_v.t_compute);
    }

    #[test]
    fn launch_overhead_floor() {
        let (g, cluster, comm) = setup();
        let relu = g.ops.iter().find(|o| o.name == "relu1").unwrap();
        let c = ParallelConfig::data_parallel(relu, 16).unwrap();
        let cost = op_cost(relu, &c, &cluster, &comm);
        assert!(cost.t_compute >= LAUNCH_OVERHEAD);
    }
}
