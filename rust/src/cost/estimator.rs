//! Whole-strategy cost evaluation (Eq. 3): per-iteration time, peak
//! memory, and the communication/computation decomposition plotted as the
//! dotted lines of Figure 6.

use crate::cluster::Cluster;
use crate::graph::Graph;
use crate::parallel::resched::CollectiveCost;
use crate::parallel::Strategy;

use super::op_cost::{edge_costs, op_cost};

/// Aggregate costs of a complete strategy.
#[derive(Debug, Clone, Copy, Default)]
pub struct StrategyCost {
    /// Per-iteration time `t(S, G, D)`.
    pub time: f64,
    /// Peak per-device memory `m(S, G, D)`.
    pub memory: f64,
    /// Communication component `c(S, G, D)` (sync + re-scheduling).
    pub comm_time: f64,
    /// Compute component.
    pub compute_time: f64,
}

/// Edge-reuse choice when evaluating a fixed strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReuseChoice {
    /// Always keep both copies (min time, max memory).
    KeepBoth,
    /// Always keep one copy (min memory, extra backward comm).
    KeepOne,
}

/// Evaluate a complete strategy with the given communication oracle.
pub fn eval_strategy(
    g: &Graph,
    s: &Strategy,
    cluster: &Cluster,
    comm: &dyn CollectiveCost,
    reuse: ReuseChoice,
) -> StrategyCost {
    let mut out = StrategyCost::default();
    for op in &g.ops {
        let c = op_cost(op, s.config(op.id), cluster, comm);
        out.memory += c.mem;
        out.compute_time += c.t_compute;
        out.comm_time += c.t_sync;
    }
    for e in &g.edges {
        let opts = edge_costs(g, e, s.config(e.src), s.config(e.dst), comm);
        let (m, t) = match reuse {
            // options are sorted by memory ascending; last = max mem/min time.
            ReuseChoice::KeepBoth => *opts.last().unwrap(),
            ReuseChoice::KeepOne => opts[0],
        };
        out.memory += m;
        out.comm_time += t;
    }
    out.time = out.compute_time + out.comm_time;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::comm::GroundTruthComm;
    use crate::graph::models::{tiny_mlp, vgg16};

    #[test]
    fn dp_strategy_has_positive_costs() {
        let g = tiny_mlp(256);
        let cluster = Cluster::paper_testbed();
        let comm = GroundTruthComm::new(cluster.clone());
        let s = Strategy::all_data_parallel(&g, 16);
        let c = eval_strategy(&g, &s, &cluster, &comm, ReuseChoice::KeepBoth);
        assert!(c.time > 0.0 && c.memory > 0.0);
        assert!(c.comm_time > 0.0, "DP must pay gradient all-reduce");
        assert!((c.time - (c.comm_time + c.compute_time)).abs() < 1e-12);
    }

    #[test]
    fn vgg_dp_memory_scale_sane() {
        // VGG16 @ batch 256 DP on 16 GPUs: activations split 16x, params
        // replicated -> a few GB per device.
        let g = vgg16(256);
        let cluster = Cluster::paper_testbed();
        let comm = GroundTruthComm::new(cluster.clone());
        let s = Strategy::all_data_parallel(&g, 16);
        let c = eval_strategy(&g, &s, &cluster, &comm, ReuseChoice::KeepBoth);
        let gb = c.memory / 1024f64.powi(3);
        assert!(gb > 1.0 && gb < 16.0, "VGG DP mem {gb} GB");
    }

    #[test]
    fn keep_one_saves_memory_costs_time() {
        let g = tiny_mlp(256);
        let cluster = Cluster::paper_testbed();
        let comm = GroundTruthComm::new(cluster.clone());
        // mixed strategy with at least one re-scheduling edge: make fc2
        // model-parallel while the rest is data-parallel.
        let mut s = Strategy::all_data_parallel(&g, 4);
        let fc2 = g.ops.iter().find(|o| o.name == "fc2").unwrap();
        let cfgs = crate::parallel::enumerate_configs(fc2, 4, 2);
        let out_axis = fc2.axes.iter().position(|a| a.name == "fc2_out").unwrap();
        let mp = cfgs.iter().find(|c| c.axis_shards(out_axis) == 4).unwrap().clone();
        s.configs[fc2.id.0] = mp;
        let both = eval_strategy(&g, &s, &cluster, &comm, ReuseChoice::KeepBoth);
        let one = eval_strategy(&g, &s, &cluster, &comm, ReuseChoice::KeepOne);
        assert!(one.memory <= both.memory);
        assert!(one.comm_time >= both.comm_time);
    }
}
