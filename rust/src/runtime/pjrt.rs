//! PJRT runtime: load AOT-compiled HLO text (`artifacts/*.hlo.txt`,
//! produced once by `python/compile/aot.py`), compile it on the PJRT CPU
//! client, execute it with host tensors.
//!
//! HLO **text** is the interchange format: jax >= 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md). Python never
//! runs on this path — the binary is self-contained once `make artifacts`
//! has been run.
//!
//! The `xla` crate is not vendored in the offline build, so the real
//! implementation is gated behind the `xla` cargo feature; the default
//! build ships an API-compatible stub whose entry points return errors
//! (everything above this layer — cost model, FT search, scheduler,
//! simulator — is pure Rust and unaffected). See DESIGN.md for enabling
//! real execution.

use std::path::PathBuf;

#[cfg(feature = "xla")]
use std::collections::HashMap;
#[cfg(feature = "xla")]
use std::path::Path;

use anyhow::Result;
#[cfg(feature = "xla")]
use anyhow::Context;

use super::tensor::HostTensor;

/// A compiled HLO module ready to execute.
#[cfg(feature = "xla")]
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact name (file stem under the artifacts dir).
    pub name: String,
}

#[cfg(feature = "xla")]
impl Executable {
    /// Execute with host tensors; returns the flattened tuple outputs.
    /// (aot.py lowers everything with `return_tuple=True`.)
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()
            .with_context(|| format!("building literals for {}", self.name))?;
        let out = self
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {}", self.name))?;
        let result = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        let parts = result.to_tuple().context("decomposing result tuple")?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

/// The PJRT CPU runtime with an executable cache (one compile per HLO
/// file per process).
#[cfg(feature = "xla")]
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: HashMap<String, std::sync::Arc<Executable>>,
}

#[cfg(feature = "xla")]
impl Runtime {
    /// CPU PJRT client rooted at an artifacts directory.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    /// PJRT platform name.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<artifacts>/<name>.hlo.txt` (cached).
    pub fn load(&mut self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let e = std::sync::Arc::new(Executable { exe, name: name.to_string() });
        self.cache.insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Whether an artifact exists (used to skip executor tests before
    /// `make artifacts`).
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts_dir.join(format!("{name}.hlo.txt")).exists()
    }
}

/// Stub executable for builds without the `xla` feature: same shape as the
/// real one so the executor and trainer compile, but it cannot be
/// constructed or run.
#[cfg(not(feature = "xla"))]
pub struct Executable {
    /// Artifact name (file stem under the artifacts dir).
    pub name: String,
}

#[cfg(not(feature = "xla"))]
impl Executable {
    /// Always fails: built without the `xla` feature.
    pub fn run(&self, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        anyhow::bail!(
            "{}: binary built without the `xla` feature — PJRT execution is \
             unavailable (see DESIGN.md)",
            self.name
        )
    }
}

/// Stub runtime for builds without the `xla` feature. `cpu()` always
/// fails, so no instance ever exists at runtime; the remaining methods
/// and fields are API parity with the real `Runtime` so downstream code
/// (trainer, benches) compiles identically under both feature sets.
#[cfg(not(feature = "xla"))]
pub struct Runtime {
    artifacts_dir: PathBuf,
}

#[cfg(not(feature = "xla"))]
impl Runtime {
    /// Always fails: built without the `xla` feature.
    pub fn cpu(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let _ = artifacts_dir;
        anyhow::bail!(
            "binary built without the `xla` feature — PJRT execution is \
             unavailable (see DESIGN.md for enabling it)"
        )
    }

    /// PJRT platform name.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Always fails: built without the `xla` feature.
    pub fn load(&mut self, name: &str) -> Result<std::sync::Arc<Executable>> {
        anyhow::bail!("cannot load `{name}`: built without the `xla` feature")
    }

    /// Whether an artifact exists on disk (works without `xla`).
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts_dir.join(format!("{name}.hlo.txt")).exists()
    }
}

/// Default artifacts directory: `$REPO/artifacts` (overridable with
/// `TENSOROPT_ARTIFACTS`).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("TENSOROPT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

#[cfg(all(test, feature = "xla"))]
mod tests {
    use super::*;

    /// End-to-end smoke test against the reference HLO from the image's
    /// xla-example (always present), independent of `make artifacts`.
    #[test]
    fn load_and_run_reference_hlo() {
        // generate a tiny HLO via the checked-in reference generator
        // output if artifacts are absent.
        let dir = default_artifacts_dir();
        let mut rt = Runtime::cpu(&dir).unwrap();
        assert_eq!(rt.platform(), "cpu");
        if !rt.has_artifact("matmul_kernel_16x16") {
            // artifacts not built yet — only assert client creation.
            return;
        }
        let exe = rt.load("matmul_kernel_16x16").unwrap();
        let a = HostTensor::f32(vec![16, 16], (0..256).map(|i| (i % 7) as f32).collect());
        let b = HostTensor::f32(vec![16, 16], (0..256).map(|i| (i % 5) as f32).collect());
        let out = exe.run(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[16, 16]);
        // spot-check one element against a host matmul.
        let (av, bv) = (a.as_f32(), b.as_f32());
        let expect: f32 = (0..16).map(|k| av[k] * bv[k * 16]).sum();
        assert!((out[0].as_f32()[0] - expect).abs() < 1e-3);
    }
}

#[cfg(all(test, not(feature = "xla")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_runtime_reports_unavailable() {
        let e = match Runtime::cpu(default_artifacts_dir()) {
            Err(e) => e,
            Ok(_) => panic!("stub Runtime must not construct"),
        };
        assert!(format!("{e}").contains("xla"));
    }
}
