//! Runtime layer: PJRT loading/execution of AOT artifacts, host tensors,
//! collectives over virtual devices, and the execution-graph engine.

pub mod collective;
pub mod executor;
pub mod pjrt;
pub mod tensor;

pub use executor::{ExecMetrics, ExecStep, Executor};
pub use pjrt::{default_artifacts_dir, Executable, Runtime};
pub use tensor::HostTensor;
