//! Collective operations over the virtual devices' host tensors — the
//! communication operators TensorOpt inserts into the execution graph
//! (§4.2: "TensorOpt uses collective operations (e.g., allreduce and
//! allgather) for all inter-device communication").
//!
//! Two all-reduce algorithms are provided: a naive reduce+broadcast and a
//! chunked ring (reduce-scatter + all-gather). On real networks the ring
//! moves `2(n-1)/n x` data instead of `2(n-1) x`; in-process the ring still
//! wins on large payloads through chunking locality, and the bench
//! `bench_micro` records the comparison.

use super::tensor::HostTensor;

/// Sum-all-reduce: every device ends with the elementwise sum.
/// Naive algorithm: accumulate into device 0, copy back.
pub fn all_reduce_naive(bufs: &mut [HostTensor]) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let len = bufs[0].len();
    for d in 1..n {
        assert_eq!(bufs[d].len(), len, "all_reduce on mismatched shapes");
        let (head, tail) = bufs.split_at_mut(d);
        let acc = head[0].as_f32_mut();
        let src = tail[0].as_f32();
        for i in 0..len {
            acc[i] += src[i];
        }
    }
    let (head, tail) = bufs.split_at_mut(1);
    let acc = head[0].as_f32();
    for b in tail.iter_mut() {
        b.as_f32_mut().copy_from_slice(acc);
    }
}

/// Ring all-reduce: reduce-scatter then all-gather over `n` equal chunks.
pub fn all_reduce_ring(bufs: &mut [HostTensor]) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let len = bufs[0].len();
    if len < n {
        return all_reduce_naive(bufs);
    }
    let chunk = len.div_ceil(n);
    let bounds: Vec<(usize, usize)> =
        (0..n).map(|c| (c * chunk, ((c + 1) * chunk).min(len))).collect();
    // reduce-scatter: at step s device d accumulates chunk (d - s - 1) mod
    // n from its ring predecessor; after n-1 steps device d owns the fully
    // reduced chunk (d+1) mod n. Within one step every device writes a
    // distinct chunk and reads a chunk its predecessor finished in the
    // previous step, so sequential iteration is race-free.
    for step in 0..n - 1 {
        for d in 0..n {
            let c = (d + 2 * n - step - 1) % n;
            let (lo, hi) = bounds[c];
            let prev = (d + n - 1) % n;
            // add prev's partial of chunk c into d's copy.
            let (a, b) = two_mut(bufs, prev, d);
            let pa = a.as_f32();
            let pb = b.as_f32_mut();
            for i in lo..hi {
                pb[i] += pa[i];
            }
        }
    }
    // each device d now owns the reduced chunk (d+1) % n; all-gather.
    for c in 0..n {
        let owner = (c + n - 1) % n;
        let (lo, hi) = bounds[c];
        let owned: Vec<f32> = bufs[owner].as_f32()[lo..hi].to_vec();
        for d in 0..n {
            if d != owner {
                bufs[d].as_f32_mut()[lo..hi].copy_from_slice(&owned);
            }
        }
    }
}

/// All-gather along axis 0: each device contributes its shard; all end
/// with the concatenation.
pub fn all_gather(bufs: &mut [HostTensor]) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let shard_shape = bufs[0].shape().to_vec();
    let mut full_shape = shard_shape.clone();
    full_shape[0] *= n;
    let mut full = Vec::with_capacity(bufs.iter().map(|b| b.len()).sum());
    for b in bufs.iter() {
        assert_eq!(b.shape(), &shard_shape[..]);
        full.extend_from_slice(b.as_f32());
    }
    for b in bufs.iter_mut() {
        *b = HostTensor::f32(full_shape.clone(), full.clone());
    }
}

/// Elementwise max all-reduce (used by the sharded-softmax stage of the
/// tensor-parallel execution graph).
pub fn all_reduce_max(bufs: &mut [HostTensor]) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let len = bufs[0].len();
    let mut acc: Vec<f32> = bufs[0].as_f32().to_vec();
    for b in bufs.iter().skip(1) {
        for (a, &v) in acc.iter_mut().zip(b.as_f32()) {
            *a = a.max(v);
        }
    }
    for b in bufs.iter_mut() {
        b.as_f32_mut().copy_from_slice(&acc);
    }
    let _ = len;
}

fn two_mut(bufs: &mut [HostTensor], a: usize, b: usize) -> (&HostTensor, &mut HostTensor) {
    assert_ne!(a, b);
    if a < b {
        let (l, r) = bufs.split_at_mut(b);
        (&l[a], &mut r[0])
    } else {
        let (l, r) = bufs.split_at_mut(a);
        (&r[0], &mut l[b])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;

    fn make(n: usize, len: usize, seed: u64) -> Vec<HostTensor> {
        let mut rng = XorShift::new(seed);
        (0..n)
            .map(|_| {
                HostTensor::f32(
                    vec![len],
                    (0..len).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
                )
            })
            .collect()
    }

    fn expected_sum(bufs: &[HostTensor]) -> Vec<f32> {
        let len = bufs[0].len();
        let mut s = vec![0.0f32; len];
        for b in bufs {
            for (i, &v) in b.as_f32().iter().enumerate() {
                s[i] += v;
            }
        }
        s
    }

    #[test]
    fn naive_allreduce_sums() {
        let mut bufs = make(4, 37, 1);
        let want = expected_sum(&bufs);
        all_reduce_naive(&mut bufs);
        for b in &bufs {
            for (got, want) in b.as_f32().iter().zip(&want) {
                assert!((got - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn ring_matches_naive() {
        for n in [2usize, 3, 4, 8] {
            for len in [8usize, 64, 1000, 1003] {
                let mut a = make(n, len, 42);
                let mut b = a.clone();
                all_reduce_naive(&mut a);
                all_reduce_ring(&mut b);
                for (x, y) in a.iter().zip(&b) {
                    for (u, v) in x.as_f32().iter().zip(y.as_f32()) {
                        assert!((u - v).abs() < 1e-3, "n={n} len={len}");
                    }
                }
            }
        }
    }

    #[test]
    fn allgather_concatenates() {
        let mut bufs: Vec<HostTensor> = (0..3)
            .map(|d| HostTensor::f32(vec![2, 2], vec![d as f32; 4]))
            .collect();
        all_gather(&mut bufs);
        for b in &bufs {
            assert_eq!(b.shape(), &[6, 2]);
            assert_eq!(b.as_f32()[0], 0.0);
            assert_eq!(b.as_f32()[4], 1.0);
            assert_eq!(b.as_f32()[8], 2.0);
        }
    }

    #[test]
    fn max_allreduce() {
        let mut bufs = vec![
            HostTensor::f32(vec![3], vec![1.0, 5.0, 2.0]),
            HostTensor::f32(vec![3], vec![4.0, 0.0, 3.0]),
        ];
        all_reduce_max(&mut bufs);
        for b in &bufs {
            assert_eq!(b.as_f32(), &[4.0, 5.0, 3.0]);
        }
    }

    #[test]
    fn single_device_noop() {
        let mut bufs = make(1, 16, 9);
        let orig = bufs.clone();
        all_reduce_ring(&mut bufs);
        assert_eq!(bufs, orig);
    }
}
