//! Host-side tensors moved between the coordinator and PJRT executions.

/// A dense host tensor (f32 or i32 payload).
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    /// Dense f32 tensor.
    F32 { shape: Vec<usize>, data: Vec<f32> },
    /// Dense i32 tensor.
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    /// f32 tensor from shape + data (lengths must agree).
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape, data }
    }

    /// i32 tensor from shape + data (lengths must agree).
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape, data }
    }

    /// All-zero f32 tensor.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor::F32 { shape, data: vec![0.0; n] }
    }

    /// The tensor shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    /// Whether the tensor has zero elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// f32 payload (panics on i32 tensors — coordinator-internal misuse).
    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32 { data, .. } => data,
            HostTensor::I32 { .. } => panic!("expected f32 tensor"),
        }
    }

    /// Mutable f32 payload (panics on i32 tensors).
    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match self {
            HostTensor::F32 { data, .. } => data,
            HostTensor::I32 { .. } => panic!("expected f32 tensor"),
        }
    }

    /// Convert to an XLA literal with this tensor's shape.
    #[cfg(feature = "xla")]
    pub fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Build from an XLA literal (f32 or i32/s32).
    #[cfg(feature = "xla")]
    pub fn from_literal(lit: &xla::Literal) -> anyhow::Result<Self> {
        let shape = lit.shape()?;
        let dims: Vec<usize> = match &shape {
            xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
            _ => anyhow::bail!("tuple literal passed to from_literal"),
        };
        match lit.ty()? {
            xla::ElementType::F32 => Ok(HostTensor::f32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(HostTensor::i32(dims, lit.to_vec::<i32>()?)),
            t => anyhow::bail!("unsupported element type {t:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_len() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        HostTensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::i32(vec![4], vec![1, 2, 3, 4]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }
}
