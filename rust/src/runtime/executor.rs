//! The distributed execution engine: TensorOpt's execution graph
//! (§4.2 "System workflow") over N *virtual devices*.
//!
//! A strategy compiles to a sequence of [`ExecStep`]s: compute segments
//! (AOT-compiled HLO run through PJRT, one invocation per device) with
//! communication operators (Rust collectives) and optimizer updates
//! inserted between them — exactly the paper's generated low-level
//! execution graph, with Python nowhere on the path.
//!
//! Virtual devices are executed sequentially within a step: the PJRT CPU
//! client already parallelizes each execution across host cores (and the
//! `xla` crate's handles are not `Sync`), so device-level threading would
//! only oversubscribe. Relative timings between strategies — what Table 4
//! reports — are preserved.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::collective;
use super::pjrt::Executable;
use super::tensor::HostTensor;

/// One operator of the execution graph.
pub enum ExecStep {
    /// Run `exe` on every device, reading `inputs` and writing `outputs`
    /// from/to the device-local buffer namespace.
    Compute { exe: Arc<Executable>, inputs: Vec<String>, outputs: Vec<String> },
    /// Shard-specific executables (e.g. the TP stage whose one-hot offset
    /// is baked per vocabulary shard): `exes[d]` runs on device `d`.
    ComputePerDevice { exes: Vec<Arc<Executable>>, inputs: Vec<String>, outputs: Vec<String> },
    /// Sum all-reduce of one buffer across devices (optionally averaging),
    /// with the ring or naive algorithm.
    AllReduceSum { buf: String, average: bool, ring: bool },
    /// Elementwise max all-reduce (sharded softmax).
    AllReduceMax { buf: String },
    /// Fused sum all-reduce of many buffers through fusion buckets of
    /// `bucket_bytes` (Horovod-style tensor fusion).
    AllReduceFused { bufs: Vec<String>, average: bool, bucket_bytes: usize },
    /// SGD update `param -= lr * grad`, elementwise, per device.
    Sgd { params: Vec<String>, grads: Vec<String>, lr: f32 },
}

/// Wall-clock accounting per step category.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecMetrics {
    /// Seconds in compute steps.
    pub compute_s: f64,
    /// Seconds in collectives.
    pub comm_s: f64,
    /// Seconds in optimizer updates.
    pub optimizer_s: f64,
    /// Executed step count.
    pub steps: usize,
}

impl ExecMetrics {
    /// Total accounted wall-clock seconds.
    pub fn total(&self) -> f64 {
        self.compute_s + self.comm_s + self.optimizer_s
    }
}

/// Executor state: one buffer namespace per virtual device.
pub struct Executor {
    /// Virtual device count.
    pub n_devices: usize,
    /// Per-device named buffers.
    pub state: Vec<HashMap<String, HostTensor>>,
    /// Accumulated time accounting.
    pub metrics: ExecMetrics,
}

impl Executor {
    /// Executor over `n_devices` empty buffer namespaces.
    pub fn new(n_devices: usize) -> Self {
        Self {
            n_devices,
            state: (0..n_devices).map(|_| HashMap::new()).collect(),
            metrics: ExecMetrics::default(),
        }
    }

    /// Install a tensor on one device.
    pub fn set(&mut self, dev: usize, name: &str, t: HostTensor) {
        self.state[dev].insert(name.to_string(), t);
    }

    /// Install the same tensor on every device (replication).
    pub fn set_replicated(&mut self, name: &str, t: &HostTensor) {
        for d in 0..self.n_devices {
            self.state[d].insert(name.to_string(), t.clone());
        }
    }

    /// Read a tensor from one device.
    pub fn get(&self, dev: usize, name: &str) -> Option<&HostTensor> {
        self.state[dev].get(name)
    }

    fn take_across(&mut self, name: &str) -> Result<Vec<HostTensor>> {
        let mut out = Vec::with_capacity(self.n_devices);
        for d in 0..self.n_devices {
            match self.state[d].remove(name) {
                Some(t) => out.push(t),
                None => bail!("buffer `{name}` missing on device {d}"),
            }
        }
        Ok(out)
    }

    fn put_across(&mut self, name: &str, bufs: Vec<HostTensor>) {
        for (d, t) in bufs.into_iter().enumerate() {
            self.state[d].insert(name.to_string(), t);
        }
    }

    /// Execute one step.
    pub fn run_step(&mut self, step: &ExecStep) -> Result<()> {
        match step {
            ExecStep::Compute { exe, inputs, outputs } => {
                let t0 = Instant::now();
                for d in 0..self.n_devices {
                    let args: Vec<HostTensor> = inputs
                        .iter()
                        .map(|n| {
                            self.state[d]
                                .get(n)
                                .cloned()
                                .with_context(|| format!("input `{n}` missing on device {d}"))
                        })
                        .collect::<Result<_>>()?;
                    let outs = exe.run(&args)?;
                    if outs.len() != outputs.len() {
                        bail!(
                            "{}: expected {} outputs, got {}",
                            exe.name,
                            outputs.len(),
                            outs.len()
                        );
                    }
                    for (name, t) in outputs.iter().zip(outs) {
                        self.state[d].insert(name.clone(), t);
                    }
                }
                self.metrics.compute_s += t0.elapsed().as_secs_f64();
            }
            ExecStep::ComputePerDevice { exes, inputs, outputs } => {
                anyhow::ensure!(exes.len() == self.n_devices, "one exe per device");
                let t0 = Instant::now();
                for d in 0..self.n_devices {
                    let args: Vec<HostTensor> = inputs
                        .iter()
                        .map(|n| {
                            self.state[d]
                                .get(n)
                                .cloned()
                                .with_context(|| format!("input `{n}` missing on device {d}"))
                        })
                        .collect::<Result<_>>()?;
                    let outs = exes[d].run(&args)?;
                    anyhow::ensure!(outs.len() == outputs.len(), "{}: output arity", exes[d].name);
                    for (name, t) in outputs.iter().zip(outs) {
                        self.state[d].insert(name.clone(), t);
                    }
                }
                self.metrics.compute_s += t0.elapsed().as_secs_f64();
            }
            ExecStep::AllReduceSum { buf, average, ring } => {
                let t0 = Instant::now();
                let mut bufs = self.take_across(buf)?;
                if *ring {
                    collective::all_reduce_ring(&mut bufs);
                } else {
                    collective::all_reduce_naive(&mut bufs);
                }
                if *average {
                    let inv = 1.0 / self.n_devices as f32;
                    for b in &mut bufs {
                        for v in b.as_f32_mut() {
                            *v *= inv;
                        }
                    }
                }
                self.put_across(buf, bufs);
                self.metrics.comm_s += t0.elapsed().as_secs_f64();
            }
            ExecStep::AllReduceMax { buf } => {
                let t0 = Instant::now();
                let mut bufs = self.take_across(buf)?;
                collective::all_reduce_max(&mut bufs);
                self.put_across(buf, bufs);
                self.metrics.comm_s += t0.elapsed().as_secs_f64();
            }
            ExecStep::AllReduceFused { bufs, average, bucket_bytes } => {
                let t0 = Instant::now();
                // pack buffers into fusion buckets, all-reduce each bucket
                // once, scatter back (Horovod's tensor fusion).
                let per_elem = 4usize;
                let cap = (bucket_bytes / per_elem).max(1);
                let mut bucket: Vec<String> = Vec::new();
                let mut bucket_len = 0usize;
                let mut flush =
                    |names: &mut Vec<String>, this: &mut Self| -> Result<()> {
                        if names.is_empty() {
                            return Ok(());
                        }
                        // concatenate on every device
                        let mut fused: Vec<HostTensor> = Vec::with_capacity(this.n_devices);
                        for d in 0..this.n_devices {
                            let mut data = Vec::new();
                            for n in names.iter() {
                                data.extend_from_slice(
                                    this.state[d]
                                        .get(n)
                                        .with_context(|| format!("fused buf `{n}` missing"))?
                                        .as_f32(),
                                );
                            }
                            let len = data.len();
                            fused.push(HostTensor::f32(vec![len], data));
                        }
                        collective::all_reduce_ring(&mut fused);
                        if *average {
                            let inv = 1.0 / this.n_devices as f32;
                            for b in &mut fused {
                                for v in b.as_f32_mut() {
                                    *v *= inv;
                                }
                            }
                        }
                        // scatter back
                        for d in 0..this.n_devices {
                            let src = fused[d].as_f32();
                            let mut off = 0usize;
                            for n in names.iter() {
                                let t = this.state[d].get_mut(n).unwrap();
                                let len = t.len();
                                t.as_f32_mut().copy_from_slice(&src[off..off + len]);
                                off += len;
                            }
                        }
                        names.clear();
                        Ok(())
                    };
                for name in bufs {
                    let len = self.state[0]
                        .get(name)
                        .with_context(|| format!("fused buf `{name}` missing"))?
                        .len();
                    if bucket_len + len > cap && !bucket.is_empty() {
                        flush(&mut bucket, self)?;
                        bucket_len = 0;
                    }
                    bucket.push(name.clone());
                    bucket_len += len;
                }
                flush(&mut bucket, self)?;
                self.metrics.comm_s += t0.elapsed().as_secs_f64();
            }
            ExecStep::Sgd { params, grads, lr } => {
                let t0 = Instant::now();
                for d in 0..self.n_devices {
                    for (p, g) in params.iter().zip(grads) {
                        let grad = self.state[d]
                            .get(g)
                            .with_context(|| format!("grad `{g}` missing on device {d}"))?
                            .as_f32()
                            .to_vec();
                        let param = self.state[d]
                            .get_mut(p)
                            .with_context(|| format!("param `{p}` missing on device {d}"))?;
                        for (w, dv) in param.as_f32_mut().iter_mut().zip(&grad) {
                            *w -= lr * dv;
                        }
                    }
                }
                self.metrics.optimizer_s += t0.elapsed().as_secs_f64();
            }
        }
        self.metrics.steps += 1;
        Ok(())
    }

    /// Execute a full execution graph in order.
    pub fn run(&mut self, steps: &[ExecStep]) -> Result<()> {
        for s in steps {
            self.run_step(s)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_step_averages() {
        let mut ex = Executor::new(4);
        for d in 0..4 {
            ex.set(d, "g", HostTensor::f32(vec![4], vec![d as f32; 4]));
        }
        ex.run_step(&ExecStep::AllReduceSum { buf: "g".into(), average: true, ring: true })
            .unwrap();
        for d in 0..4 {
            assert_eq!(ex.get(d, "g").unwrap().as_f32(), &[1.5; 4]);
        }
        assert!(ex.metrics.comm_s >= 0.0);
    }

    #[test]
    fn sgd_updates_params() {
        let mut ex = Executor::new(2);
        ex.set_replicated("w", &HostTensor::f32(vec![2], vec![1.0, 2.0]));
        ex.set_replicated("dw", &HostTensor::f32(vec![2], vec![0.5, 0.5]));
        ex.run_step(&ExecStep::Sgd {
            params: vec!["w".into()],
            grads: vec!["dw".into()],
            lr: 0.1,
        })
        .unwrap();
        for d in 0..2 {
            let w = ex.get(d, "w").unwrap().as_f32();
            assert!((w[0] - 0.95).abs() < 1e-6 && (w[1] - 1.95).abs() < 1e-6);
        }
    }

    #[test]
    fn fused_allreduce_matches_per_tensor() {
        let mut a = Executor::new(3);
        let mut b = Executor::new(3);
        for d in 0..3 {
            for (i, name) in ["g0", "g1", "g2"].iter().enumerate() {
                let t = HostTensor::f32(vec![5], vec![(d + i) as f32; 5]);
                a.set(d, name, t.clone());
                b.set(d, name, t);
            }
        }
        for name in ["g0", "g1", "g2"] {
            a.run_step(&ExecStep::AllReduceSum { buf: name.into(), average: true, ring: true })
                .unwrap();
        }
        b.run_step(&ExecStep::AllReduceFused {
            bufs: vec!["g0".into(), "g1".into(), "g2".into()],
            average: true,
            bucket_bytes: 32, // force multiple buckets
        })
        .unwrap();
        for d in 0..3 {
            for name in ["g0", "g1", "g2"] {
                assert_eq!(
                    a.get(d, name).unwrap().as_f32(),
                    b.get(d, name).unwrap().as_f32(),
                    "dev {d} buf {name}"
                );
            }
        }
    }

    #[test]
    fn missing_buffer_errors() {
        let mut ex = Executor::new(2);
        let r = ex.run_step(&ExecStep::AllReduceSum {
            buf: "nope".into(),
            average: false,
            ring: false,
        });
        assert!(r.is_err());
    }
}
