//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment vendors no registry crates (see `rust/Cargo.toml`),
//! so this path dependency provides the subset of the `anyhow` API the
//! workspace actually uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros. Errors are plain
//! message strings with `context` chaining; no backtraces, no downcasting.
//! Swapping in the real crate is a one-line change in `Cargo.toml`.

use std::fmt;

/// A string-backed error value, API-compatible with `anyhow::Error` for the
/// operations this workspace performs (construction, context, display).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line (outermost context first, like anyhow).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// Like anyhow: any std error converts into [`Error`] via `?`.
/// ([`Error`] itself intentionally does not implement `std::error::Error`,
/// which is what makes this blanket impl coherent.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — a std result defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &str) -> Result<i64> {
        let n: i64 = v.parse()?;
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("42").unwrap(), 42);
        let e = parse("nope").unwrap_err();
        assert!(format!("{e}").contains("invalid digit"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: inner");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).is_err());
        assert!(format!("{}", f(11).unwrap_err()).contains("too big"));
    }
}
