//! Differential lockdown of the struct-of-arrays frontier engine (ISSUE 9).
//!
//! Every production frontier operation is asserted **bit-identical** —
//! `f64::to_bits`, no tolerances — to the frozen pre-SoA implementation in
//! `tensoropt::frontier::reference` on seeded adversarial inputs:
//!
//! - dense ties (small-integer coordinates),
//! - ±0.0 (compare equal, different bit patterns — the stable sort must
//!   preserve whichever came first),
//! - subnormal f64s (ε-scaling by `1 - THIN_EPS` rounds to zero there),
//! - coordinates sitting exactly on the ε-thinning boundary,
//! - the all-costs-zero case, where 3-D reduce must degenerate to the
//!   paper's 2-D staircase.
//!
//! The generators never produce NaN (frontier comparisons `unwrap` a
//! `partial_cmp`, in both engines) or negative coordinates other than
//! `-0.0` (costs are sums of nonnegative leaf costs in the search).

use tensoropt::frontier::{
    pareto_indices, reduce, reference, Frontier, Mode, Trace, Tuple, THIN_EPS,
};
use tensoropt::prop_assert;
use tensoropt::util::ptest;
use tensoropt::util::rng::XorShift;

/// One adversarial coordinate. Small integers force exact ties; the
/// ε-scaled and ε-boundary values land pairs of points exactly on the
/// thinning threshold; subnormals shake out underflow in the ε scan.
fn coord(rng: &mut XorShift) -> f64 {
    match rng.below(8) {
        0 => rng.below(6) as f64,
        1 => 0.0,
        2 => -0.0,
        3 => f64::from_bits(rng.below(4) as u64 + 1), // subnormals: 5e-324 ..
        4 => 1.0 - THIN_EPS,
        5 => (rng.below(6) as f64) * (1.0 - THIN_EPS),
        6 => (rng.below(6) as f64) * (1.0 + THIN_EPS),
        _ => rng.f64() * 10.0,
    }
}

/// Raw tuple cloud; `zero_cost` exercises the 2-D degenerate case.
fn cloud(rng: &mut XorShift, n: usize, zero_cost: bool) -> Vec<Tuple> {
    (0..n)
        .map(|_| {
            let c = if zero_cost { 0.0 } else { coord(rng) };
            Tuple::with_cost(coord(rng), coord(rng), c, Trace::empty())
        })
        .collect()
}

fn bits(t: &Tuple) -> (u64, u64, u64) {
    (t.mem.to_bits(), t.time.to_bits(), t.cost.to_bits())
}

fn assert_bits_eq(got: &Frontier, want: &Frontier, what: &str) -> Result<(), String> {
    prop_assert!(got.len() == want.len(), "{what}: {} vs {} tuples", got.len(), want.len());
    for (i, (x, y)) in got.tuples.iter().zip(&want.tuples).enumerate() {
        prop_assert!(bits(x) == bits(y), "{what}: tuple {i}: {x:?} vs {y:?}");
    }
    Ok(())
}

const MODES: [Mode; 3] = [Mode::Pareto, Mode::TimeOnly, Mode::MemOnly];

/// `reduce` (Algorithm 1 + ε-thinning) in all three modes.
#[test]
fn reduce_matches_reference() {
    ptest::check(
        "diff-reduce",
        ptest::Config { cases: 300, ..ptest::Config::default() },
        |rng| {
            let ts = cloud(rng, rng.below(40), rng.below(2) == 0);
            for mode in MODES {
                let got = reduce(ts.clone(), mode);
                let want = reference::reduce(ts.clone(), mode);
                assert_bits_eq(&got, &want, &format!("reduce {mode:?}"))?;
                prop_assert!(got.is_valid() || mode != Mode::Pareto, "invariant");
            }
            Ok(())
        },
    );
}

/// `product` ⊗ — including the singleton fast path (`n == 1` either side)
/// and unsorted inputs (a raw `Frontier` that never went through reduce).
#[test]
fn product_matches_reference() {
    ptest::check(
        "diff-product",
        ptest::Config { cases: 300, ..ptest::Config::default() },
        |rng| {
            let zero = rng.below(2) == 0;
            let mk = |rng: &mut XorShift| -> Frontier {
                let n = 1 + rng.below(10);
                let ts = cloud(rng, n, zero);
                if rng.below(2) == 0 {
                    reduce(ts, Mode::Pareto)
                } else {
                    Frontier { tuples: ts } // raw: exercises the sort path
                }
            };
            let (a, b) = (mk(rng), mk(rng));
            for mode in MODES {
                let got = a.product(&b, mode);
                let want = reference::product(&a, &b, mode);
                assert_bits_eq(&got, &want, &format!("product {mode:?}"))?;
            }
            Ok(())
        },
    );
}

/// `union` ∪ and the k-way `union_many` against the reference fold
/// (union_many of parts ≡ reduce of the concatenation).
#[test]
fn union_matches_reference() {
    ptest::check(
        "diff-union",
        ptest::Config { cases: 300, ..ptest::Config::default() },
        |rng| {
            let zero = rng.below(2) == 0;
            let a = reduce(cloud(rng, rng.below(12), zero), Mode::Pareto);
            let b = reduce(cloud(rng, rng.below(12), zero), Mode::Pareto);
            for mode in MODES {
                assert_bits_eq(
                    &a.union(&b, mode),
                    &reference::union(&a, &b, mode),
                    &format!("union {mode:?}"),
                )?;
            }
            let parts: Vec<Frontier> = (0..rng.range(1, 7))
                .map(|_| reduce(cloud(rng, rng.below(12), zero), Mode::Pareto))
                .collect();
            let concat: Vec<Tuple> =
                parts.iter().flat_map(|f| f.tuples.iter().cloned()).collect();
            for mode in MODES {
                assert_bits_eq(
                    &Frontier::union_many(parts.clone(), mode),
                    &reference::reduce(concat.clone(), mode),
                    &format!("union_many {mode:?}"),
                )?;
            }
            Ok(())
        },
    );
}

/// Every selector, with budgets drawn from the same adversarial palette so
/// they frequently land exactly on a tuple's coordinate.
#[test]
fn selectors_match_reference() {
    ptest::check(
        "diff-selectors",
        ptest::Config { cases: 300, ..ptest::Config::default() },
        |rng| {
            let f = reduce(cloud(rng, rng.below(30), rng.below(2) == 0), Mode::Pareto);
            let (mb, dl, usd) = (coord(rng), coord(rng), coord(rng));
            let pairs: [(Option<&Tuple>, Option<&Tuple>, &str); 6] = [
                (f.min_time(), reference::min_time(&f), "min_time"),
                (f.min_cost(), reference::min_cost(&f), "min_cost"),
                (f.min_time_within(mb), reference::min_time_within(&f, mb), "min_time_within"),
                (
                    f.min_cost_within(mb, dl),
                    reference::min_cost_within(&f, mb, dl),
                    "min_cost_within",
                ),
                (
                    f.min_time_within_cost(mb, usd),
                    reference::min_time_within_cost(&f, mb, usd),
                    "min_time_within_cost",
                ),
                (f.min_mem(), f.tuples.first(), "min_mem"),
            ];
            for (got, want, what) in pairs {
                match (got, want) {
                    (None, None) => {}
                    (Some(x), Some(y)) => {
                        prop_assert!(bits(x) == bits(y), "{what}: {x:?} vs {y:?}")
                    }
                    _ => prop_assert!(false, "{what}: Some/None mismatch"),
                }
            }
            Ok(())
        },
    );
}

/// The sort-based `pareto_indices` sweep against the retired O(n²)
/// pairwise scan, on the full adversarial palette.
#[test]
fn pareto_indices_matches_reference() {
    ptest::check(
        "diff-pareto-indices",
        ptest::Config { cases: 400, ..ptest::Config::default() },
        |rng| {
            let n = rng.below(50);
            let pts: Vec<(f64, f64, f64)> =
                (0..n).map(|_| (coord(rng), coord(rng), coord(rng))).collect();
            let got = pareto_indices(&pts);
            let want = reference::pareto_indices(&pts);
            prop_assert!(got == want, "index sets differ on {pts:?}: {got:?} vs {want:?}");
            Ok(())
        },
    );
}

/// Deterministic spot checks of the cases the fuzzers are seeded toward,
/// kept explicit so a regression names the exact construction.
#[test]
fn fixed_adversarial_cases() {
    let t = |m: f64, s: f64, c: f64| Tuple::with_cost(m, s, c, Trace::empty());
    let sub = f64::from_bits(1); // smallest positive subnormal
    let cases: Vec<Vec<Tuple>> = vec![
        // ±0.0 everywhere: compare equal, sort must be stable across bits.
        vec![t(0.0, -0.0, 0.0), t(-0.0, 0.0, -0.0), t(0.0, 0.0, 0.0)],
        // subnormals: (1 - ε)·sub rounds down; thinning must not diverge.
        vec![t(sub, 1.0, 0.0), t(sub + sub, 1.0, 0.0), t(0.0, 2.0, 0.0)],
        // exact ε-boundary pair: q ε-dominates t iff q.time·(1-ε) <= t.time.
        vec![
            t(1.0, 1.0, 0.0),
            t(2.0, 1.0 - THIN_EPS, 0.0),
            t(3.0, (1.0 - THIN_EPS) * (1.0 - THIN_EPS), 0.0),
        ],
        // exhaustive duplicates.
        vec![t(2.0, 2.0, 2.0); 6],
    ];
    for (i, ts) in cases.iter().enumerate() {
        for mode in MODES {
            let got = reduce(ts.clone(), mode);
            let want = reference::reduce(ts.clone(), mode);
            assert_eq!(got.len(), want.len(), "case {i} {mode:?}");
            for (x, y) in got.tuples.iter().zip(&want.tuples) {
                assert_eq!(bits(x), bits(y), "case {i} {mode:?}");
            }
        }
    }
}
