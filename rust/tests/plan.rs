//! Planner-engine integration tests: the PR's acceptance criteria.
//!
//! - A `Session::profile` sweep and a `FrontierCache::curve` over 4
//!   parallelisms perform exactly one model-space build per (model,
//!   batch) and produce frontiers bit-identical to the pre-refactor
//!   cold-search path (`frontier_search` on the sub-cluster).
//! - Concurrent callers racing on one cold key share a single search
//!   (single-flight; the old documented `sched/cache.rs` race).
//! - Property: for random graphs/clusters/modes/billings, memoized,
//!   incremental, and store-round-tripped planner results are
//!   bit-identical to a from-scratch `frontier_search`.

use std::sync::Arc;

use tensoropt::cluster::{Cluster, DeviceSpec, LinkKind, Machine};
use tensoropt::coordinator::Session;
use tensoropt::cost::comm::CommModel;
use tensoropt::cost::pricing::{self, Billing};
use tensoropt::frontier::Mode;
use tensoropt::ft::{frontier_search, frontier_search_filtered, FtOptions, FtResult};
use tensoropt::graph::models::{self, tiny_mlp};
use tensoropt::graph::Op;
use tensoropt::parallel::ParallelConfig;
use tensoropt::plan::{ConfigFilter, PlanRequest, Planner, Served};
use tensoropt::prop_assert;
use tensoropt::sched::FrontierCache;
use tensoropt::util::ptest;

/// The pre-refactor cold-search path, replicated exactly: profile-comm on
/// the machine-major sub-cluster, priced (or not) at its rental rate.
fn reference(
    model: &str,
    batch: i64,
    base: &Cluster,
    d: u32,
    mode: Mode,
    billing: Option<Billing>,
    filter: ConfigFilter,
) -> FtResult {
    let g = models::by_name(model, batch).expect("zoo model");
    let sub = base.sub_cluster(d as usize);
    let comm = CommModel::profile(&sub);
    let mut opts = FtOptions::new(sub.n_devices() as u32).sequential().with_mode(mode);
    opts.usd_hour = billing.map_or(0.0, |b| pricing::usd_hour(&sub, b));
    match filter {
        ConfigFilter::Full => frontier_search(&g, &sub, &comm, opts),
        ConfigFilter::NoReplication => {
            let f = |_op: &Op, c: &ParallelConfig| c.replication() == 1;
            frontier_search_filtered(&g, &sub, &comm, opts, Some(&f))
        }
    }
}

/// Bit-identity of two search results: frontier objectives down to the
/// last ulp, pins, and every unrolled strategy.
fn check_identical(a: &FtResult, b: &FtResult, what: &str) -> Result<(), String> {
    prop_assert!(
        a.frontier.len() == b.frontier.len(),
        "{what}: frontier sizes {} vs {}",
        a.frontier.len(),
        b.frontier.len()
    );
    for (i, (x, y)) in a.frontier.tuples.iter().zip(&b.frontier.tuples).enumerate() {
        prop_assert!(
            x.mem.to_bits() == y.mem.to_bits()
                && x.time.to_bits() == y.time.to_bits()
                && x.cost.to_bits() == y.cost.to_bits(),
            "{what}: tuple {i} differs: ({}, {}, {}) vs ({}, {}, {})",
            x.mem,
            x.time,
            x.cost,
            y.mem,
            y.time,
            y.cost
        );
        let (sa, _) = a.strategy_of(x);
        let (sb, _) = b.strategy_of(y);
        prop_assert!(sa.configs == sb.configs, "{what}: strategy {i} differs");
    }
    prop_assert!(a.forced == b.forced, "{what}: pins differ");
    prop_assert!(a.n_heuristic == b.n_heuristic, "{what}: n_heuristic differs");
    Ok(())
}

fn assert_identical(a: &FtResult, b: &FtResult, what: &str) {
    if let Err(e) = check_identical(a, b, what) {
        panic!("{e}");
    }
}

/// Acceptance: `Session::profile` + `FrontierCache::curve` over 4
/// parallelisms = one space build per (model, batch), 4 leaf builds, and
/// frontiers bit-identical to the pre-refactor cold path.
#[test]
fn profile_sweep_and_curve_share_one_space_build() {
    let cluster = Cluster::with_gpus(8);
    let planner = Arc::new(Planner::new().with_threads(2));
    let parallelisms = [1u32, 2, 4, 8];

    let session = Session::builder(tiny_mlp(256), cluster.clone())
        .planner(Arc::clone(&planner))
        .build();
    let rows = session.profile(&parallelisms);
    assert_eq!(rows.len(), 4);
    let after_profile = planner.stats();
    assert_eq!(after_profile.space_builds, 1, "one space build for the whole sweep");
    assert_eq!(after_profile.leaf_builds, 4, "one leaf build per parallelism");
    assert_eq!(after_profile.searches(), 4);

    // the scheduler cache on the same planner reuses all four searches.
    let cache = FrontierCache::new_shared(cluster.clone(), Arc::clone(&planner));
    let curve = cache.curve("tiny", 256, &parallelisms);
    let s = planner.stats();
    assert_eq!(s.space_builds, 1, "curve reuses the session's space");
    assert_eq!(s.leaf_builds, 4, "no new leaf builds");
    assert_eq!(s.searches(), 4, "no new searches");
    assert_eq!(s.memo_hits, 4, "all four curve points are memo hits");

    // bit-identity against the pre-refactor cold path, plus row agreement.
    let fp = planner.register_cluster(&cluster);
    let budget = session.mem_budget();
    for (row, &d) in rows.iter().zip(&parallelisms) {
        let raw = reference(
            "tiny",
            256,
            &cluster,
            d,
            Mode::Pareto,
            Some(Billing::OnDemand),
            ConfigFilter::Full,
        );
        let resp = planner
            .plan(
                &PlanRequest::builder("tiny", 256, &fp, d)
                    .billing(Billing::OnDemand)
                    .build()
                    .unwrap(),
            )
            .unwrap();
        assert_eq!(resp.served, Served::Memo);
        assert_identical(&resp.result, &raw, "sweep");
        assert_eq!(row.best_time, raw.frontier.min_time_within(budget).map(|t| t.time));
        assert_eq!(curve.est_time(d), row.best_time);
    }

    // a second (model, batch) gets its own (single) space build.
    let session2 = Session::builder(tiny_mlp(128), cluster.clone())
        .planner(Arc::clone(&planner))
        .build();
    session2.profile(&parallelisms);
    assert_eq!(planner.stats().space_builds, 2, "one more per (model, batch)");
}

/// The old documented cold-key race, pinned: concurrent `curve` callers
/// on one cold key run exactly one FT search between them.
#[test]
fn concurrent_cold_curves_share_one_search() {
    let cluster = Cluster::with_gpus(4);
    let planner = Arc::new(Planner::new().with_threads(2));
    let cache =
        Arc::new(FrontierCache::new_shared(cluster.clone(), Arc::clone(&planner)));
    let mut handles = Vec::new();
    for _ in 0..8 {
        let cache = Arc::clone(&cache);
        handles.push(std::thread::spawn(move || {
            let curve = cache.curve("tiny", 256, &[2]);
            curve.est_time(2).expect("tiny fits at 2 devices")
        }));
    }
    let times: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for t in &times {
        assert_eq!(t.to_bits(), times[0].to_bits(), "all callers share one result");
    }
    let s = planner.stats();
    assert_eq!(s.searches(), 1, "single-flight: one search for 8 racing callers");
    assert_eq!(s.space_builds, 1);
    assert_eq!(s.leaf_builds, 1);
}

/// Restart warm-serving: plans persisted by one planner are served by a
/// fresh planner from the store, bit-identically and without searching.
#[test]
fn store_roundtrip_serves_warm_after_restart() {
    let dir = std::env::temp_dir().join("tensoropt_plan_restart_test");
    let path = dir.join("plans.json");
    let _ = std::fs::remove_file(&path);
    let cluster = Cluster::with_gpus(4);

    let first = Planner::new().with_threads(2);
    first.attach_store(&path).unwrap();
    let fp = first.register_cluster(&cluster);
    let req2 = PlanRequest::builder("tiny", 256, &fp, 2)
        .billing(Billing::OnDemand)
        .build()
        .unwrap();
    let req4 = PlanRequest::builder("tiny", 256, &fp, 4)
        .billing(Billing::OnDemand)
        .build()
        .unwrap();
    let a2 = first.plan(&req2).unwrap();
    let a4 = first.plan(&req4).unwrap();
    assert!(!a2.served.is_warm() && !a4.served.is_warm());
    first.flush_store().unwrap();

    // "restart": a fresh planner over the same store file.
    let second = Planner::new().with_threads(2);
    assert_eq!(second.attach_store(&path).unwrap(), 2, "two persisted plans");
    let fp2 = second.register_cluster(&cluster);
    for (req, cold) in [(req2, a2), (req4, a4)] {
        let req = req.to_builder().cluster(&fp2).build().unwrap();
        let warm = second.plan(&req).unwrap();
        assert_eq!(warm.served, Served::Store);
        assert_identical(&warm.result, &cold.result, "store restart");
    }
    assert_eq!(second.stats().searches(), 0, "restart ran no searches");
    assert_eq!(second.stats().store_serves, 2);
    let _ = std::fs::remove_file(&path);
}

fn testbed(which: u64) -> Cluster {
    match which % 3 {
        0 => Cluster::with_gpus(4),
        1 => Cluster::with_gpus(6),
        _ => Cluster::from_machines(
            "2xA100+2xV100 prop",
            vec![
                Machine::new(DeviceSpec::a100(), 2, LinkKind::NvLink),
                Machine::new(DeviceSpec::v100(), 2, LinkKind::NvLink),
            ],
            LinkKind::IbRdma,
        ),
    }
}

/// Property: memoized, incremental (re-billed and re-sized), and
/// store-round-tripped planner results are bit-identical to a
/// from-scratch `frontier_search`.
#[test]
fn prop_planner_matches_from_scratch_search() {
    let dir = std::env::temp_dir().join("tensoropt_plan_prop_test");
    let _ = std::fs::create_dir_all(&dir);
    let mut case = 0u64;
    ptest::check(
        "planner-vs-scratch",
        ptest::Config { cases: 10, seed: 0x9E37 },
        |rng| {
            case += 1;
            let batch = [64i64, 128, 256][rng.below(3)];
            let cluster = testbed(rng.next_u64());
            let n = cluster.n_devices();
            let d = 1 + rng.below(n) as u32;
            let mode = [Mode::Pareto, Mode::TimeOnly, Mode::MemOnly][rng.below(3)];
            let billings = [None, Some(Billing::OnDemand), Some(Billing::Spot)];
            let billing = billings[rng.below(3)];
            let filter = if rng.below(4) == 0 {
                ConfigFilter::NoReplication
            } else {
                ConfigFilter::Full
            };

            let store_path = dir.join(format!("case_{case}.json"));
            let _ = std::fs::remove_file(&store_path);
            let planner = Planner::new().with_threads(2);
            planner.attach_store(&store_path).map_err(|e| e.to_string())?;
            let fp = planner.register_cluster(&cluster);
            let req = PlanRequest::builder("tiny", batch, &fp, d)
                .mode(mode)
                .filter(filter)
                .billing_opt(billing)
                .build()
                .map_err(|e| e.to_string())?;

            // cold == scratch
            let cold = planner.plan(&req).map_err(|e| e.to_string())?;
            let scratch = reference("tiny", batch, &cluster, d, mode, billing, filter);
            check_identical(&cold.result, &scratch, "cold")?;

            // memo: the identical request returns the shared result.
            let memo = planner.plan(&req).map_err(|e| e.to_string())?;
            prop_assert!(memo.served == Served::Memo, "expected memo hit");
            prop_assert!(
                Arc::ptr_eq(&memo.result, &cold.result),
                "memo must share the result"
            );

            // incremental re-billing at the same parallelism.
            let rebilled = billings[rng.below(3)];
            let req_b = req
                .to_builder()
                .billing_opt(rebilled)
                .build()
                .map_err(|e| e.to_string())?;
            let inc = planner.plan(&req_b).map_err(|e| e.to_string())?;
            let scratch_b =
                reference("tiny", batch, &cluster, d, mode, rebilled, filter);
            check_identical(&inc.result, &scratch_b, "rebilled")?;

            // incremental re-sizing (schedule replay at another d).
            let d2 = 1 + rng.below(n) as u32;
            let req_d =
                req.to_builder().parallelism(d2).build().map_err(|e| e.to_string())?;
            let re = planner.plan(&req_d).map_err(|e| e.to_string())?;
            let scratch_d = reference("tiny", batch, &cluster, d2, mode, billing, filter);
            check_identical(&re.result, &scratch_d, "resized")?;

            // store round-trip through a fresh planner.
            planner.flush_store().map_err(|e| e.to_string())?;
            let fresh = Planner::new().with_threads(2);
            fresh.attach_store(&store_path).map_err(|e| e.to_string())?;
            let fp2 = fresh.register_cluster(&cluster);
            let req_s = req.to_builder().cluster(&fp2).build().map_err(|e| e.to_string())?;
            let stored = fresh.plan(&req_s).map_err(|e| e.to_string())?;
            prop_assert!(stored.served == Served::Store, "expected a store serve");
            check_identical(&stored.result, &scratch, "stored")?;
            let _ = std::fs::remove_file(&store_path);
            Ok(())
        },
    );
}
