//! Correctness lockdown for the pipeline cut sweep (ISSUE 10).
//!
//! Three properties, all bit-exact (`f64::to_bits`, no tolerances):
//!
//! 1. **Differential**: the planner's interval-memoized sweep — stage
//!    searches served through the plan memo, replayed elimination
//!    schedules and all — equals brute-force enumeration of every cut
//!    vector with cold per-stage searches, point for point and plan for
//!    plan, priced and unpriced.
//! 2. **Thread invariance**: the joint frontier and the composed plans
//!    are identical at 1, 2 and 8 threads (the PR 9 contract extended to
//!    the pipeline layer).
//! 3. **Warm accounting**: one leaf build and one search per
//!    (interval, width) on the first sweep, zero new work on a repeat
//!    sweep, and same-shape intervals of a uniform transformer share one
//!    recorded elimination schedule.

use tensoropt::cluster::Cluster;
use tensoropt::cost::pricing::Billing;
use tensoropt::frontier::Mode;
use tensoropt::ft::pipeline::{self, ColdSweepCtx, PipelineOpts};
use tensoropt::graph::models::{transformer96, transformer_lm, TransformerCfg};
use tensoropt::graph::Graph;
use tensoropt::plan::{PipelineRequest, PlanRequest, Planner};

fn tiny_transformer() -> Graph {
    transformer_lm(TransformerCfg {
        batch: 8,
        seq: 4,
        hidden: 16,
        ffn_mult: 2,
        layers: 2,
        vocab: 16,
    })
}

/// A fresh planner with the tiny transformer registered, plus the
/// pipeline request mirroring `opts` at the given width / thread budget.
fn setup(
    gpus: u32,
    threads: usize,
    billing: Option<Billing>,
    opts: &PipelineOpts,
) -> (Planner, PipelineRequest) {
    let planner = Planner::new().with_threads(threads);
    let fp = planner.register_cluster(&Cluster::with_gpus(gpus as usize));
    let (id, batch) = planner.register_graph(tiny_transformer());
    let preq = PipelineRequest::new(
        PlanRequest::builder(&id, batch, &fp, gpus)
            .billing_opt(billing)
            .threads(threads)
            .build()
            .unwrap(),
    )
    .with_max_stages(opts.max_stages)
    .with_micro_batches(opts.micro_batches)
    .with_max_cuts(opts.max_cuts);
    (planner, preq)
}

#[test]
fn planner_sweep_matches_brute_force_bit_for_bit() {
    let opts =
        PipelineOpts { max_stages: 3, micro_batches: 4, max_cuts: 4, mode: Mode::Pareto };
    for billing in [None, Some(Billing::OnDemand)] {
        let (planner, preq) = setup(4, 1, billing, &opts);
        let resp = planner.plan_pipeline(&preq).unwrap();
        assert!(!resp.frontier.tuples.is_empty());

        let g = tiny_transformer();
        let spine = g.mark_linear_spine();
        let cluster = Cluster::with_gpus(4);
        let ctx = ColdSweepCtx {
            graph: &g,
            spine: &spine,
            cluster: &cluster,
            devices: 4,
            max_mesh_dims: 2,
            threads: 1,
            billing,
        };
        let brute = pipeline::brute_force_sweep(&ctx, &opts);
        assert_eq!(resp.frontier.len(), brute.len(), "billing {billing:?}");
        for (t, p) in resp.frontier.tuples.iter().zip(&brute) {
            assert_eq!(
                (t.mem.to_bits(), t.time.to_bits(), t.cost.to_bits()),
                (p.mem.to_bits(), p.time.to_bits(), p.cost.to_bits()),
                "billing {billing:?}"
            );
        }
        for (plan, p) in resp.plans.iter().zip(&brute) {
            assert_eq!(plan, &p.plan, "billing {billing:?}");
        }
        if billing.is_some() {
            assert!(resp.frontier.tuples.iter().all(|t| t.cost > 0.0));
        }
    }
}

#[test]
fn sweep_is_thread_count_invariant() {
    let opts =
        PipelineOpts { max_stages: 3, micro_batches: 8, max_cuts: 5, mode: Mode::Pareto };
    let (p1, q1) = setup(8, 1, Some(Billing::Spot), &opts);
    let base = p1.plan_pipeline(&q1).unwrap();
    assert!(!base.frontier.tuples.is_empty());
    for threads in [2usize, 8] {
        let (pn, qn) = setup(8, threads, Some(Billing::Spot), &opts);
        let other = pn.plan_pipeline(&qn).unwrap();
        assert_eq!(base.frontier.len(), other.frontier.len(), "{threads} threads");
        for (a, b) in base.frontier.tuples.iter().zip(&other.frontier.tuples) {
            assert_eq!(
                (a.mem.to_bits(), a.time.to_bits(), a.cost.to_bits()),
                (b.mem.to_bits(), b.time.to_bits(), b.cost.to_bits()),
                "{threads} threads"
            );
        }
        assert_eq!(base.plans, other.plans, "{threads} threads");
    }
}

/// Sequential (threads = 1) planner so every counter is deterministic:
/// the sweep touches each (interval, width) exactly once, a repeat sweep
/// does zero new work, and same-shape single-layer intervals of the
/// uniform transformer replay one recorded elimination schedule instead
/// of rediscovering it.
#[test]
fn cut_sweep_builds_each_interval_leaf_exactly_once() {
    // max_cuts = 8 keeps all 7 clean seams of the 2-layer spine, so the
    // bound set contains the same one-layer interval at two positions.
    let opts =
        PipelineOpts { max_stages: 3, micro_batches: 8, max_cuts: 8, mode: Mode::Pareto };
    let (planner, preq) = setup(8, 1, None, &opts);

    let r1 = planner.plan_pipeline(&preq).unwrap();
    let s1 = planner.stats();
    assert!(r1.stage_searches > 1);
    assert_eq!(r1.stage_warm, 0, "first sweep: every stage key is new");
    assert_eq!(r1.n_intervals, r1.stage_searches, "every interval is separable");
    assert_eq!(
        s1.leaf_builds, r1.stage_searches,
        "exactly one leaf-table build per (interval, width)"
    );
    assert_eq!(
        s1.searches(),
        r1.stage_searches,
        "exactly one search per (interval, width)"
    );
    assert!(
        s1.cold_searches < s1.searches(),
        "same-shape intervals must replay a shared schedule ({} cold of {})",
        s1.cold_searches,
        s1.searches()
    );
    assert_eq!(s1.pipe_cut_sweeps, 1);
    assert_eq!(s1.pipe_stage_searches, r1.stage_searches);
    assert_eq!(s1.pipe_stage_warm, 0);
    assert!(s1.pipe_interval_builds > 0);
    assert!(
        s1.pipe_interval_hits > 0,
        "an interval reused at another width must hit the interval memo"
    );

    let r2 = planner.plan_pipeline(&preq).unwrap();
    let s2 = planner.stats();
    assert_eq!(r2.stage_warm, r2.stage_searches, "repeat sweep serves all-warm");
    assert!((r2.stage_warm_rate() - 1.0).abs() < 1e-12);
    assert_eq!(s2.leaf_builds, s1.leaf_builds, "repeat sweep builds nothing");
    assert_eq!(s2.searches(), s1.searches(), "repeat sweep searches nothing");
    assert_eq!(s2.pipe_interval_builds, s1.pipe_interval_builds);
    assert!(s2.pipe_interval_hits > s1.pipe_interval_hits);
    assert!(s2.pipe_interval_hit_rate() > s1.pipe_interval_hit_rate());
}

/// The tentpole scale claim: the O(L^2)-interval sweep finishes on the
/// 96-layer transformer and re-serves entirely from the memo.
#[test]
#[ignore = "heavy: run via the release-mode CI step (cargo test --release -- --ignored)"]
fn transformer96_cut_sweep_completes_and_rewarms() {
    let planner = Planner::new();
    let fp = planner.register_cluster(&Cluster::with_gpus(8));
    let (id, batch) = planner.register_graph(transformer96(32));
    let preq = PipelineRequest::new(PlanRequest::builder(&id, batch, &fp, 8).build().unwrap())
        .with_max_stages(4)
        .with_micro_batches(8)
        .with_max_cuts(8);
    let r1 = planner.plan_pipeline(&preq).unwrap();
    assert!(!r1.frontier.tuples.is_empty());
    assert!(r1.n_cuts > 0);
    assert!(
        r1.stage_searches > r1.n_cuts,
        "the stage table covers more than one width per cut"
    );
    let r2 = planner.plan_pipeline(&preq).unwrap();
    assert_eq!(r2.stage_warm, r2.stage_searches, "repeat sweep serves all-warm");
}
