//! Determinism lockdown of the parallel FT engine (ISSUE 9).
//!
//! The batched elimination engine computes every batch member's new table
//! from the pre-batch state and applies mutations sequentially, so a cold
//! `frontier_search` must be **bit-identical** — `f64::to_bits`, no
//! tolerances — across `util::par` thread counts (1/2/8), across repeated
//! runs, on all three heterogeneous testbeds, with and without pricing.
//! The recorded `ElimSchedule` replay must reproduce a fresh run exactly.
//!
//! The heavy 96-layer transformer variants (the graph `bench_ft_large`
//! times, where multi-node batches actually fan out) are `#[ignore]`d and
//! run in the dedicated release-mode CI step: debug-mode timeouts must
//! never mask them.

use tensoropt::cluster::Cluster;
use tensoropt::cost::comm::GroundTruthComm;
use tensoropt::frontier::{Frontier, Mode};
use tensoropt::ft::eliminate::WorkGraph;
use tensoropt::ft::{frontier_search, ElimSchedule, FtOptions, FtResult, SearchSpace};
use tensoropt::graph::builder::GraphBuilder;
use tensoropt::graph::models::transformer96;
use tensoropt::graph::Graph;
use tensoropt::util::rng::XorShift;

/// Seeded random spine graph: a dense trunk with random residual blocks,
/// so elimination sees chains, branches and (via the residual adds)
/// parallel-edge merges.
fn random_graph(rng: &mut XorShift, idx: usize) -> Graph {
    let batch = [16, 32, 64][rng.below(3)];
    let mut b = GraphBuilder::new(&format!("rand{idx}"), batch);
    let x = b.input("x", &[("batch", batch), ("feat", 32)]);
    let mut t = b.dense("d0", &x, 32);
    for l in 0..rng.range(2, 5) {
        if rng.below(2) == 0 {
            let f1 = b.dense(&format!("l{l}_f1"), &t, 64);
            let g = b.activation(&format!("l{l}_act"), &f1);
            let f2 = b.dense(&format!("l{l}_f2"), &g, 32);
            let r = b.add(&format!("l{l}_res"), &f2, &t);
            t = b.layer_norm(&format!("l{l}_ln"), &r);
        } else {
            let f = b.dense(&format!("l{l}_d"), &t, 48);
            t = b.activation(&format!("l{l}_a"), &f);
        }
    }
    let h = b.dense("head", &t, 8);
    b.loss("loss", &h, 8);
    b.build()
}

/// The three heterogeneous testbeds (PR 6) — mixed device generations,
/// mixed link speeds, mixed machine sizes.
fn testbeds() -> Vec<Cluster> {
    vec![Cluster::mixed_generation(), Cluster::straggler_link(), Cluster::big_little()]
}

fn assert_frontier_bits(a: &Frontier, b: &Frontier, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: frontier sizes differ");
    for (i, (x, y)) in a.tuples.iter().zip(&b.tuples).enumerate() {
        assert_eq!(
            (x.mem.to_bits(), x.time.to_bits(), x.cost.to_bits()),
            (y.mem.to_bits(), y.time.to_bits(), y.cost.to_bits()),
            "{what}: tuple {i} differs"
        );
    }
}

fn assert_results_match(a: &FtResult, b: &FtResult, what: &str) {
    assert_frontier_bits(&a.frontier, &b.frontier, what);
    assert_eq!(a.forced, b.forced, "{what}: heuristic pins differ");
    assert_eq!(a.n_heuristic, b.n_heuristic, "{what}: n_heuristic differs");
}

/// Cold searches at 1/2/8 threads are bit-identical, on every testbed,
/// priced and unpriced, across seeded random spine graphs.
#[test]
fn cold_search_bit_identical_across_threads() {
    let mut rng = XorShift::new(0x915E_D);
    for (c, cluster) in testbeds().into_iter().enumerate() {
        let comm = GroundTruthComm::new(cluster.clone());
        for gi in 0..3 {
            let g = random_graph(&mut rng, c * 10 + gi);
            for priced in [false, true] {
                let opts_for = |threads: usize| {
                    let mut o = FtOptions::new(4).with_mode(Mode::Pareto);
                    o.threads = threads;
                    if priced {
                        o = o.with_pricing(cluster.usd_hour());
                    }
                    o
                };
                let base = frontier_search(&g, &cluster, &comm, opts_for(1));
                assert!(!base.frontier.is_empty(), "empty frontier on {}", g.name);
                for threads in [2, 8] {
                    let r = frontier_search(&g, &cluster, &comm, opts_for(threads));
                    let what = format!("{} t={threads} priced={priced}", g.name);
                    assert_results_match(&base, &r, &what);
                }
            }
        }
    }
}

/// Two runs of the identical search are bit-identical (no hidden
/// iteration-order or allocation dependence), including with pricing.
#[test]
fn repeated_runs_bit_identical() {
    let mut rng = XorShift::new(0xD17E);
    let cluster = Cluster::mixed_generation();
    let comm = GroundTruthComm::new(cluster.clone());
    let g = random_graph(&mut rng, 99);
    let opts = || {
        let mut o = FtOptions::new(4).with_pricing(cluster.usd_hour());
        o.threads = 8;
        o
    };
    let a = frontier_search(&g, &cluster, &comm, opts());
    let b = frontier_search(&g, &cluster, &comm, opts());
    assert_results_match(&a, &b, "repeat");
}

/// Replaying a recorded schedule reproduces the fresh run bit-for-bit on
/// the random spine graphs (the in-crate unit test covers the fixed zoo
/// graphs; this covers the generator's branch/merge mixtures).
#[test]
fn replay_bit_identical_on_random_graphs() {
    let mut rng = XorShift::new(0x2E91A);
    let cluster = Cluster::paper_testbed();
    let comm = GroundTruthComm::new(cluster.clone());
    for gi in 0..4 {
        let g = random_graph(&mut rng, gi);
        let space = SearchSpace::build(&g, &cluster, &comm, FtOptions::new(4).sequential(), None);
        let spine = g.mark_linear_spine();

        let mut fresh = WorkGraph::init(&space, &spine);
        let mut schedule = ElimSchedule::new();
        fresh.run_recording(&mut schedule);
        let (chain_a, nodes_a, edges_a, forced_a, nh_a) = fresh.into_chain();

        let mut re = WorkGraph::init(&space, &spine);
        re.replay(&schedule, Some(&forced_a));
        let (chain_b, nodes_b, edges_b, forced_b, nh_b) = re.into_chain();

        assert_eq!(chain_a, chain_b, "{}: chains differ", g.name);
        assert_eq!(forced_a, forced_b);
        assert_eq!(nh_a, nh_b);
        for (fa, fb) in nodes_a.iter().flatten().zip(nodes_b.iter().flatten()) {
            assert_frontier_bits(fa, fb, &format!("{}: node frontier", g.name));
        }
        for (ta, tb) in edges_a.iter().zip(&edges_b) {
            for (ra, rb) in ta.iter().zip(tb) {
                for (fa, fb) in ra.iter().zip(rb) {
                    assert_frontier_bits(fa, fb, &format!("{}: edge table", g.name));
                }
            }
        }
    }
}

// ---------------------------------------------------------------- heavy
// (release-mode CI step: `cargo test --release -- --ignored`)

/// Thread-count invariance on the 96-layer transformer (the zoo's
/// `transformer96`, the graph `bench_ft_large` times) — hundreds of
/// multi-member elimination batches actually fan out here.
#[test]
#[ignore = "heavy: run via the release-mode CI step (cargo test --release -- --ignored)"]
fn transformer96_thread_determinism() {
    let g = transformer96(32);
    let cluster = Cluster::paper_testbed();
    let comm = GroundTruthComm::new(cluster.clone());
    let opts_for = |threads: usize| {
        let mut o = FtOptions::new(4).with_pricing(cluster.usd_hour());
        o.threads = threads;
        o
    };
    let a = frontier_search(&g, &cluster, &comm, opts_for(1));
    let b = frontier_search(&g, &cluster, &comm, opts_for(8));
    assert!(!a.frontier.is_empty());
    assert_results_match(&a, &b, "transformer96 1 vs 8 threads");
}

/// Replay-equivalence (the PR 4 property) extended to the 96-layer graph:
/// a recorded schedule replayed on a fresh working graph reproduces the
/// cold elimination bit-for-bit, at different thread counts.
#[test]
#[ignore = "heavy: run via the release-mode CI step (cargo test --release -- --ignored)"]
fn transformer96_replay_matches_cold() {
    let g = transformer96(32);
    let cluster = Cluster::paper_testbed();
    let comm = GroundTruthComm::new(cluster.clone());
    let opts_for = |threads: usize| {
        let mut o = FtOptions::new(4);
        o.threads = threads;
        o
    };
    let spine = g.mark_linear_spine();

    let space_cold = SearchSpace::build(&g, &cluster, &comm, opts_for(8), None);
    let mut cold = WorkGraph::init(&space_cold, &spine);
    let mut schedule = ElimSchedule::new();
    cold.run_recording(&mut schedule);
    let (chain_a, nodes_a, edges_a, forced_a, nh_a) = cold.into_chain();

    let space_re = SearchSpace::build(&g, &cluster, &comm, opts_for(1), None);
    let mut re = WorkGraph::init(&space_re, &spine);
    re.replay(&schedule, Some(&forced_a));
    let (chain_b, nodes_b, edges_b, forced_b, nh_b) = re.into_chain();

    assert_eq!(chain_a, chain_b);
    assert_eq!(forced_a, forced_b);
    assert_eq!(nh_a, nh_b);
    for (fa, fb) in nodes_a.iter().flatten().zip(nodes_b.iter().flatten()) {
        assert_frontier_bits(fa, fb, "transformer96 node frontier");
    }
    for (ta, tb) in edges_a.iter().zip(&edges_b) {
        for (ra, rb) in ta.iter().zip(tb) {
            for (fa, fb) in ra.iter().zip(rb) {
                assert_frontier_bits(fa, fb, "transformer96 edge table");
            }
        }
    }
}
