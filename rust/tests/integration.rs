//! Cross-module integration tests: the full FT pipeline against the
//! simulator, session-level searches on real models, strategy unrolling
//! consistency, and (when artifacts are built) the PJRT execution engine.

use tensoropt::cluster::Cluster;
use tensoropt::coordinator::{FindResult, SearchOption, Session};
use tensoropt::cost::comm::CommModel;
use tensoropt::cost::estimator::{eval_strategy, ReuseChoice};
use tensoropt::ft::{frontier_search, FtOptions};
use tensoropt::graph::models;
use tensoropt::sim::{simulate, SimConfig};
use tensoropt::util::ptest;

const GB: f64 = 1024.0 * 1024.0 * 1024.0;

/// FT on the real RNN model: frontier strategies re-evaluate to (nearly)
/// their frontier costs, and the simulator confirms the ordering.
#[test]
fn ft_frontier_consistent_with_estimator_and_sim() {
    let g = models::rnn_lm(256);
    let cluster = Cluster::paper_testbed();
    let comm = CommModel::profile(&cluster);
    let r = frontier_search(&g, &cluster, &comm, FtOptions::new(16));
    assert!(r.frontier.len() >= 2, "rnn frontier should have a trade-off");

    let lo = r.frontier.min_mem().unwrap();
    let hi = r.frontier.min_time().unwrap();
    let (s_lo, _) = r.strategy_of(lo);
    let (s_hi, _) = r.strategy_of(hi);
    let c_lo = eval_strategy(&g, &s_lo, &cluster, &comm, ReuseChoice::KeepOne);
    let c_hi = eval_strategy(&g, &s_hi, &cluster, &comm, ReuseChoice::KeepBoth);
    // min-mem strategy uses less memory; min-time strategy less time.
    assert!(c_lo.memory <= c_hi.memory * 1.05, "{} vs {}", c_lo.memory / GB, c_hi.memory / GB);
    assert!(c_hi.time <= c_lo.time * 1.05);

    // simulator agrees on the time ordering.
    let sim_lo = simulate(&g, &s_lo, &cluster, &SimConfig::default());
    let sim_hi = simulate(&g, &s_hi, &cluster, &SimConfig::default());
    assert!(sim_hi.time <= sim_lo.time * 1.10, "{} vs {}", sim_hi.time, sim_lo.time);
}

/// Paper §5.1 headline: every large model's frontier has a knee — time
/// rises sharply below it, flattens above it.
#[test]
fn turning_point_exists_for_large_models() {
    let cluster = Cluster::paper_testbed();
    for model in ["rnn", "transformer"] {
        let g = models::by_name(model, 256).unwrap();
        let comm = CommModel::profile(&cluster);
        let r = frontier_search(&g, &cluster, &comm, FtOptions::new(16));
        let f = &r.frontier;
        assert!(f.len() >= 2, "{model}: frontier too small");
        let spread = f.min_mem().unwrap().time / f.min_time().unwrap().time;
        assert!(spread > 1.0, "{model}: no time spread on the frontier");
    }
}

/// Session mini-time on the transformer fits the 16 GB V100 budget.
#[test]
fn session_mini_time_respects_memory() {
    let session = Session::builder(
        models::by_name("transformer", 256).unwrap(),
        Cluster::paper_testbed(),
    )
    .build();
    let FindResult::Plan(p) =
        session.find_strategy(&SearchOption::MiniTime { parallelism: 16 }).unwrap()
    else {
        panic!()
    };
    assert!(p.est_memory <= session.mem_budget());
    assert!(p.est_time > 0.0);
}

/// Property: for random (model, device-count) pairs, unrolled frontier
/// strategies always cover every operator with a configuration on the
/// right device count.
#[test]
fn prop_unrolled_strategies_are_complete() {
    ptest::check(
        "unroll-complete",
        ptest::Config { cases: 6, seed: 0xF7 },
        |rng| {
            let d = *rng.choose(&[2u32, 4, 8]);
            let g = match rng.below(3) {
                0 => models::tiny_mlp(64),
                1 => models::tiny_resnet(8),
                _ => models::bert_like_test(8),
            };
            let cluster = Cluster::with_gpus(d as usize);
            let comm = CommModel::profile(&cluster);
            let r = frontier_search(&g, &cluster, &comm, FtOptions::new(d));
            crate::require(!r.frontier.is_empty(), "empty frontier")?;
            for (s, _, _) in r.all_strategies() {
                crate::require(s.configs.len() == g.n_ops(), "missing op config")?;
                for cfg in &s.configs {
                    crate::require(
                        cfg.n_devices() == d || cfg.n_devices() == 1,
                        "wrong device count",
                    )?;
                }
            }
            Ok(())
        },
    );
}

fn require(cond: bool, msg: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Full-stack smoke (needs `make artifacts`): train DP and TP briefly on
/// the real PJRT executor; losses must be finite and comparable.
#[test]
fn executor_dp_and_tp_agree_on_scale() {
    use tensoropt::coordinator::{train_dp, train_tp, TrainerCfg};
    if !tensoropt::runtime::default_artifacts_dir().join("manifest.txt").exists() {
        eprintln!("skipping executor test: run `make artifacts`");
        return;
    }
    let cfg = TrainerCfg { steps: 5, log_every: 0, ..Default::default() };
    let dp = train_dp(&cfg).unwrap();
    let tp = train_tp(&cfg).unwrap();
    // same model/init scheme: initial losses both near ln(512).
    assert!((dp.losses[0] - 6.24).abs() < 1.5, "dp init {}", dp.losses[0]);
    assert!((tp.losses[0] - 6.24).abs() < 1.5, "tp init {}", tp.losses[0]);
}
