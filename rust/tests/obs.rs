//! Integration tests for the observability layer: a traced planner sweep
//! covering every `Served` variant, the sched timeline events, and the
//! JSONL/chrome exports of real (not hand-built) traces.
//!
//! Tests that enable the process-wide recorder serialize on a lock — the
//! recorder is process-global and the test harness runs threads in
//! parallel.

use std::sync::{Mutex, MutexGuard, OnceLock};

use tensoropt::cluster::Cluster;
use tensoropt::cost::pricing::Billing;
use tensoropt::obs::{self, Attr, Record};
use tensoropt::plan::{PlanRequest, Planner};
use tensoropt::sched::{
    run_workload, FrontierCache, JobSpec, Policy, RescaleModel, SchedConfig,
};
use tensoropt::util::codec::Json;

/// Serialize tests that toggle the global recorder; recover from a
/// poisoned lock (a failed test elsewhere must not cascade).
fn global_recorder_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

fn served_attr(r: &Record) -> Option<&str> {
    match (r.name(), r.attr("served")) {
        ("plan.request", Some(Attr::Str(s))) => Some(s.as_str()),
        _ => None,
    }
}

#[test]
fn traced_plan_sweep_covers_every_served_variant() {
    let _guard = global_recorder_lock();
    obs::enable();
    obs::global().drain(); // discard leftovers from other tests

    let cluster = Cluster::with_gpus(4);
    let dir = std::env::temp_dir().join("tensoropt_obs_it_store");
    let _ = std::fs::create_dir_all(&dir);
    let store = dir.join("plans.json");
    let _ = std::fs::remove_file(&store);

    {
        let p = Planner::new();
        p.attach_store(&store).unwrap();
        let fp = p.register_cluster(&cluster);
        let req = PlanRequest::builder("tiny", 256, &fp, 4).build().unwrap();
        assert_eq!(p.plan(&req).unwrap().served.name(), "cold");
        assert_eq!(p.plan(&req).unwrap().served.name(), "memo");
        // Same topology, new billing stamps: the incremental re-bill path.
        let rebill = req.to_builder().billing(Billing::Spot).build().unwrap();
        assert_eq!(p.plan(&rebill).unwrap().served.name(), "incremental");
        p.flush_store().unwrap();
    }
    {
        // Fresh planner + attached store = restart: served from the store.
        let p = Planner::new();
        p.attach_store(&store).unwrap();
        let fp = p.register_cluster(&cluster);
        assert_eq!(
            p.plan(&PlanRequest::builder("tiny", 256, &fp, 4).build().unwrap())
                .unwrap()
                .served
                .name(),
            "store"
        );
    }

    let records = obs::global().drain();
    obs::disable();
    let _ = std::fs::remove_file(&store);

    // Every Served variant appears as a plan.request span's served attr.
    let served: Vec<&str> = records.iter().filter_map(served_attr).collect();
    for want in ["cold", "memo", "incremental", "store"] {
        assert!(served.contains(&want), "no plan.request served={want} in {served:?}");
    }

    // The cold request carries the per-phase spans, parented under it.
    let cold_id = records
        .iter()
        .find_map(|r| match r {
            Record::Span(s) if served_attr(r) == Some("cold") => Some(s.id),
            _ => None,
        })
        .unwrap();
    for phase in ["plan.space_build", "plan.leaf_build", "plan.search", "plan.ldp"] {
        assert!(
            records.iter().any(|r| matches!(
                r,
                Record::Span(s) if s.name == phase && s.parent == Some(cold_id)
            )),
            "phase span {phase} missing under the cold plan.request"
        );
    }
    // The search span says which kind of search ran, and the elimination
    // loop emitted per-step events with frontier sizes.
    assert!(records.iter().any(|r| matches!(
        (r.name(), r.attr("kind")),
        ("plan.search", Some(Attr::Str(_)))
    )));
    assert!(records
        .iter()
        .any(|r| r.name() == "ft.elim_step" && r.attr("frontier_tuples").is_some()));

    // The whole trace round-trips through the JSONL codec exactly, and the
    // chrome export is one valid JSON document with one entry per record.
    let text = obs::render_jsonl(&records);
    assert_eq!(obs::parse_jsonl(&text).unwrap(), records);
    let chrome = Json::parse(&obs::render_chrome(&records)).unwrap();
    let events = chrome.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), records.len());
}

#[test]
fn planner_metrics_registry_supersedes_stats() {
    // No recorder needed: the planner's per-instance registry always
    // counts, and stats() is a view over it.
    let p = Planner::new();
    let fp = p.register_cluster(&Cluster::with_gpus(4));
    let req = PlanRequest::builder("tiny", 256, &fp, 4).build().unwrap();
    p.plan(&req).unwrap();
    p.plan(&req).unwrap();
    let m = p.metrics();
    assert_eq!(m.counter("plan.cold_searches"), 1);
    assert_eq!(m.counter("plan.memo_hits"), 1);
    let s = p.stats();
    assert_eq!(s.cold_searches, 1);
    assert_eq!(s.memo_hits, 1);
    let lat = m.histogram("plan.latency.cold").unwrap();
    assert_eq!(lat.n, 1);
    assert!(lat.mean() > 0.0);
    assert!(m.histogram("plan.latency.memo").is_some());
    let sizes = m.histogram("plan.frontier_points").unwrap();
    assert_eq!(sizes.n, 2, "both responses observe the frontier size");
}

#[test]
fn traced_workload_emits_sched_timeline() {
    let _guard = global_recorder_lock();
    obs::enable();
    obs::global().drain();

    let cluster = Cluster::with_gpus(4);
    let cache = FrontierCache::new(cluster.clone());
    let mut cfg = SchedConfig::for_cluster(&cluster);
    cfg.rescale = RescaleModel { base_s: 1e-4, reshard_bw: 10e9 };
    let jobs: Vec<JobSpec> = (0..2usize)
        .map(|i| JobSpec {
            id: i,
            name: format!("j{i}"),
            model: "tiny".into(),
            batch: 256,
            iterations: 2000,
            priority: 1.0,
            arrival: i as f64 * 0.001,
            budget_usd: None,
            deadline_s: None,
        })
        .collect();
    let report = run_workload(&jobs, &cluster, Policy::ElasticFrontier, &cache, &cfg);
    let records = obs::global().drain();
    obs::disable();

    let workload = records
        .iter()
        .find(|r| r.name() == "sched.workload")
        .expect("sched.workload span");
    assert_eq!(workload.attr("policy"), Some(&Attr::Str("elastic-frontier".into())));
    assert!(workload.attr("makespan").is_some());
    let completions = records.iter().filter(|r| r.name() == "sched.job_complete").count();
    assert_eq!(completions, jobs.len());
    assert!(
        records.iter().any(|r| r.name() == "sched.alloc_round"),
        "at least one allocation round"
    );
    // Profiling misses ran under sched.curve spans, and each feasible
    // point's ground-truth execution shows up as a sim.run span.
    assert!(records.iter().any(|r| r.name() == "sched.curve"));
    let sims = records.iter().filter(|r| r.name() == "sim.run").count();
    assert!(sims > 0, "simulator runs traced");
    // Drift samples flow into the trace stream too when enabled.
    assert!(
        records.iter().any(|r| r.name() == "drift.sample"),
        "drift samples emitted as events"
    );
    assert!(report.makespan > 0.0);
}
