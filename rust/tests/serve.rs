//! Serving-layer acceptance tests (ISSUE PR 7):
//!
//! - a coalesced mixed-parallelism burst builds **exactly one search
//!   space per (model, batch)** and serves results bit-identical to
//!   direct planner calls — both on the deterministic `serve_batch` path
//!   and the threaded, windowed `serve` path;
//! - the sharded LRU **never evicts a pinned (in-flight) entry**, and
//!   service-level evictions under a tiny budget are counted and mirrored
//!   into the planner memo without corrupting results;
//! - under seeded saturation (zero queue depth, warmed hot set) the shed
//!   sequence is **deterministic**: two identical services produce the
//!   same outcome for every request in the schedule;
//! - a `FrontierCache` with an attached service produces **bit-identical
//!   curves** to the direct path while its misses land in the service's
//!   metrics, and it still completes (direct fallback) when everything
//!   sheds.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use tensoropt::cluster::Cluster;
use tensoropt::frontier::Frontier;
use tensoropt::ft::FtResult;
use tensoropt::plan::{PlanRequest, Planner};
use tensoropt::sched::FrontierCache;
use tensoropt::serve::{
    approx_result_bytes, generate, PlanService, RejectReason, ServeConfig, ServeOutcome,
    ServeRequest, ServeSource, ShardedStore, TrafficCfg,
};

fn setup(gpus: usize, cfg: ServeConfig) -> (Arc<Planner>, String, Arc<PlanService>) {
    let planner = Arc::new(Planner::new().with_threads(2));
    let fp = planner.register_cluster(&Cluster::with_gpus(gpus));
    let service = Arc::new(PlanService::new(Arc::clone(&planner), cfg));
    (planner, fp, service)
}

fn req(model: &str, batch: i64, fp: &str, d: u32) -> PlanRequest {
    PlanRequest::builder(model, batch, fp, d).build().unwrap()
}

/// Bitwise frontier equality — the serving layer must never change what
/// the planner computes, only how it is shared.
fn assert_same_frontier(a: &FtResult, b: &FtResult, what: &str) {
    assert_eq!(a.frontier.len(), b.frontier.len(), "{what}: frontier size");
    for (x, y) in a.frontier.tuples.iter().zip(&b.frontier.tuples) {
        assert_eq!(
            (x.mem.to_bits(), x.time.to_bits(), x.cost.to_bits()),
            (y.mem.to_bits(), y.time.to_bits(), y.cost.to_bits()),
            "{what}: tuple bits"
        );
    }
}

#[test]
fn batched_burst_builds_one_space_per_model_batch() {
    let (planner, fp, service) = setup(8, ServeConfig::default());
    // mixed burst: two (model, batch) identities, duplicated parallelisms.
    let ds_256 = [1u32, 2, 4, 8, 2, 4];
    let ds_128 = [2u32, 8];
    let burst: Vec<ServeRequest> = ds_256
        .iter()
        .map(|&d| ServeRequest::new("a", req("tiny", 256, &fp, d)))
        .chain(ds_128.iter().map(|&d| ServeRequest::new("b", req("tiny", 128, &fp, d))))
        .collect();

    let outcomes = service.serve_batch(&burst);
    assert_eq!(outcomes.len(), burst.len());
    let responses: Vec<_> = outcomes
        .into_iter()
        .map(|o| o.unwrap().served().expect("nothing sheds at default depth").clone())
        .collect();

    let s = planner.stats();
    assert_eq!(s.space_builds, 2, "exactly one space build per (model, batch)");
    assert_eq!(s.leaf_builds, 6, "one leaf per distinct (model, batch, d): 4 + 2");
    let sv = service.stats();
    assert_eq!(sv.groups, 2, "one coalesced sweep per (model, batch)");
    assert_eq!(sv.riders, 6, "everyone but the two leaders rode");
    assert_eq!(sv.misses, 8);
    assert_eq!(sv.hits, 0);

    // bit-identical to direct planner calls on a fresh engine.
    let fresh = Planner::new().with_threads(2);
    let fresh_fp = fresh.register_cluster(&Cluster::with_gpus(8));
    for (resp, (model, batch, d)) in responses.iter().zip(
        ds_256
            .iter()
            .map(|&d| ("tiny", 256i64, d))
            .chain(ds_128.iter().map(|&d| ("tiny", 128i64, d))),
    ) {
        let direct = fresh.plan(&req(model, batch, &fresh_fp, d)).unwrap();
        assert_same_frontier(&resp.result, &direct.result, "batched burst");
    }

    // replaying the burst is all store hits: no new planner work at all.
    let replay = service.serve_batch(&burst);
    assert!(replay
        .iter()
        .all(|o| matches!(o.as_ref().unwrap().served().unwrap().source, ServeSource::Store)));
    assert_eq!(planner.stats().searches(), s.searches(), "replay never touched the planner");
    assert_eq!(service.stats().hits, 8);
}

#[test]
fn windowed_concurrent_burst_coalesces_into_one_sweep() {
    let cfg = ServeConfig {
        coalesce_window: Duration::from_millis(150),
        ..ServeConfig::default()
    };
    let (planner, fp, service) = setup(8, cfg);
    let ds = [1u32, 2, 4, 8, 2, 4];
    let responses: Vec<_> = std::thread::scope(|scope| {
        let handles: Vec<_> = ds
            .iter()
            .map(|&d| {
                let service = Arc::clone(&service);
                let request = ServeRequest::new("t", req("tiny", 256, &fp, d));
                scope.spawn(move || {
                    let out = service.serve(&request).unwrap();
                    out.served().expect("no shedding at default depth").clone()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let s = planner.stats();
    assert_eq!(s.space_builds, 1, "one space build for the whole concurrent burst");
    assert_eq!(s.leaf_builds, 4, "one leaf per distinct parallelism");
    // every member of one group saw the same member count; the leader(s)
    // swept the union. With a 150ms window all six coalesce, but the
    // assertion that matters for the planner is pinned above either way.
    assert!(service.stats().groups >= 1);

    let fresh = Planner::new().with_threads(2);
    let fresh_fp = fresh.register_cluster(&Cluster::with_gpus(8));
    for (resp, &d) in responses.iter().zip(&ds) {
        let direct = fresh.plan(&req("tiny", 256, &fresh_fp, d)).unwrap();
        assert_same_frontier(&resp.result, &direct.result, "windowed burst");
    }
}

fn fake_result() -> Arc<FtResult> {
    Arc::new(FtResult {
        frontier: Frontier::default(),
        configs: Arc::new(Vec::new()),
        forced: HashMap::new(),
        n_heuristic: 0,
        log2_space: 0.0,
    })
}

#[test]
fn lru_never_evicts_pinned_entries() {
    // one shard, budget for ~2 empty-frontier entries (128 bytes each).
    let bytes = approx_result_bytes(&fake_result());
    let store = ShardedStore::new(1, 2 * bytes + bytes / 2);
    let key = |d: u32| req("tiny", 256, "fp", d);

    let pinned_key = key(1);
    let _pin = store.pin(&pinned_key);
    assert!(store.insert(&pinned_key, fake_result()).is_empty());

    // flood well past the budget: the pinned key must survive every wave.
    for d in 2..10 {
        let evicted = store.insert(&key(d), fake_result());
        assert!(
            !evicted.contains(&pinned_key),
            "pinned entry evicted at wave {d}: {evicted:?}"
        );
        assert!(store.get(&pinned_key).is_some(), "pinned entry must stay readable");
    }
    assert!(store.stats().bytes > 0);
    assert_eq!(store.stats().pinned, 1);

    // once unpinned, the (now coldest) entry becomes fair game.
    drop(_pin);
    assert_eq!(store.stats().pinned, 0);
    let mut gone = false;
    for d in 10..20 {
        if store.insert(&key(d), fake_result()).contains(&pinned_key) {
            gone = true;
            break;
        }
    }
    assert!(gone, "unpinned cold entry was never evicted");
    assert!(store.get(&pinned_key).is_none());
}

#[test]
fn tiny_budget_counts_evictions_and_keeps_results_correct() {
    // a budget far below one real frontier's footprint: every insert
    // evicts whatever else is resident, and the planner memo is trimmed
    // with it — yet replans still serve bit-identical results.
    let cfg = ServeConfig { shard_budget_bytes: 1, shards: 1, ..ServeConfig::default() };
    let (planner, fp, service) = setup(4, cfg);
    let ds = [1u32, 2, 4];
    let burst: Vec<ServeRequest> =
        ds.iter().map(|&d| ServeRequest::new("t", req("tiny", 256, &fp, d))).collect();
    let first: Vec<_> = service
        .serve_batch(&burst)
        .into_iter()
        .map(|o| o.unwrap().served().unwrap().clone())
        .collect();
    assert!(service.stats().evictions > 0, "tiny budget must evict");
    let searches_after_first = planner.stats().searches();

    // nothing stayed resident, so the replay is all misses again — and
    // because evictions were mirrored into the planner memo, these are
    // honest replans (not memo hits), still bit-identical to the first
    // pass.
    let again: Vec<_> = service
        .serve_batch(&burst)
        .into_iter()
        .map(|o| o.unwrap().served().unwrap().clone())
        .collect();
    assert_eq!(service.stats().hits, 0, "1-byte budget keeps nothing");
    for (a, b) in first.iter().zip(&again) {
        assert_same_frontier(&a.result, &b.result, "post-eviction replan");
    }
    assert!(
        planner.stats().searches() > searches_after_first,
        "evicted memo entries force real replans, not memo hits"
    );
}

#[test]
fn sheds_are_deterministic_under_seeded_saturation() {
    let outcome_tags = || -> Vec<String> {
        let cfg = ServeConfig {
            max_queue_depth: 0, // every store miss sheds
            coalesce_window: Duration::ZERO,
            ..ServeConfig::default()
        };
        let (_planner, fp, service) = setup(8, cfg);
        // warm the Zipf head at every sampled parallelism so hits flow
        // even with a zero-depth queue.
        for d in [1u32, 2, 4, 8] {
            service.warm(&req("tiny", 256, &fp, d)).unwrap();
        }
        let traffic = TrafficCfg { seed: 41, requests: 120, ..Default::default() };
        let requests: Vec<ServeRequest> =
            generate(&traffic, &fp).into_iter().map(|a| a.request).collect();
        service
            .serve_batch(&requests)
            .into_iter()
            .map(|o| match o.unwrap() {
                ServeOutcome::Served(r) => format!("served:{}", r.source.name()),
                ServeOutcome::Rejected(r) => {
                    assert!(matches!(r.reason, RejectReason::QueueFull { .. }));
                    format!("shed:{}:{}", r.reason.name(), r.shard)
                }
            })
            .collect()
    };
    let a = outcome_tags();
    let b = outcome_tags();
    assert_eq!(a, b, "same seed, same config => identical outcome sequence");
    assert!(a.iter().any(|t| t.starts_with("served:store_hit")), "warmed head hits");
    assert!(a.iter().any(|t| t.starts_with("shed:queue_full")), "cold tail sheds");
}

#[test]
fn frontier_cache_routes_misses_through_attached_service() {
    let cluster = Cluster::with_gpus(8);
    let parallelisms = [1u32, 2, 4, 8];

    // direct path (no service) for the reference curve.
    let direct_planner = Arc::new(Planner::new().with_threads(2));
    let direct = FrontierCache::new_shared(cluster.clone(), Arc::clone(&direct_planner));
    let reference = direct.curve("tiny", 256, &parallelisms);

    // served path: same planner config, misses through the service.
    let served_planner = Arc::new(Planner::new().with_threads(2));
    let service = Arc::new(PlanService::new(
        Arc::clone(&served_planner),
        ServeConfig::default(),
    ));
    let cache = FrontierCache::new_shared(cluster.clone(), Arc::clone(&served_planner))
        .with_service(Arc::clone(&service));
    let curve = cache.curve("tiny", 256, &parallelisms);

    assert_eq!(curve.points.len(), reference.points.len());
    for (a, b) in curve.points.iter().zip(&reference.points) {
        assert_eq!(a.parallelism, b.parallelism);
        assert_eq!(
            a.est_time.map(f64::to_bits),
            b.est_time.map(f64::to_bits),
            "est_time at d={}",
            a.parallelism
        );
        assert_eq!(
            a.sim_time.map(f64::to_bits),
            b.sim_time.map(f64::to_bits),
            "sim_time at d={}",
            a.parallelism
        );
        assert_eq!(a.min_memory.to_bits(), b.min_memory.to_bits());
        assert_eq!(a.usd_hour.to_bits(), b.usd_hour.to_bits());
    }

    // the misses landed in the service's accounting (one coalesced sweep).
    let sv = service.stats();
    assert_eq!(sv.requests, 4, "one serve per curve miss");
    assert_eq!(sv.misses, 4);
    assert_eq!(sv.groups, 1, "one sweep for the whole curve");

    // warm repeat: the frontier cache absorbs it before the service.
    cache.curve("tiny", 256, &parallelisms);
    assert_eq!(service.stats().requests, 4, "curve hits never reach the service");

    // saturated service: sheds fall back to the direct path, the curve is
    // still complete and identical.
    let sat_planner = Arc::new(Planner::new().with_threads(2));
    let sat_service = Arc::new(PlanService::new(
        Arc::clone(&sat_planner),
        ServeConfig { max_queue_depth: 0, ..ServeConfig::default() },
    ));
    let sat_cache = FrontierCache::new_shared(cluster, Arc::clone(&sat_planner))
        .with_service(Arc::clone(&sat_service));
    let sat_curve = sat_cache.curve("tiny", 256, &parallelisms);
    assert_eq!(sat_service.stats().shed, 4, "all four misses shed");
    for (a, b) in sat_curve.points.iter().zip(&reference.points) {
        assert_eq!(a.est_time.map(f64::to_bits), b.est_time.map(f64::to_bits));
    }
}
