//! Heterogeneous-cluster integration tests: FT determinism across thread
//! counts on mixed-generation hardware, sub-cluster spec/link preservation
//! under arbitrary subsets, and the scheduler's topology-awareness gap.

use tensoropt::cluster::{Cluster, DeviceSpec, LinkKind, Machine};
use tensoropt::cost::comm::CommModel;
use tensoropt::ft::{frontier_search, FtOptions};
use tensoropt::graph::models;
use tensoropt::sched::{run_workload, FrontierCache, Policy, ProfileCurve, SchedConfig, Workload};

fn mixed_small() -> Cluster {
    Cluster::from_machines(
        "2xA100+2xV100 test",
        vec![
            Machine::new(DeviceSpec::a100(), 2, LinkKind::NvLink),
            Machine::new(DeviceSpec::v100(), 2, LinkKind::Pcie),
        ],
        LinkKind::IbRdma,
    )
}

fn straggler_small() -> Cluster {
    let mut c = Cluster::from_machines(
        "3x2xV100 straggler test",
        vec![
            Machine::new(DeviceSpec::v100(), 2, LinkKind::NvLink),
            Machine::new(DeviceSpec::v100(), 2, LinkKind::NvLink),
            Machine::new(DeviceSpec::v100(), 2, LinkKind::NvLink),
        ],
        LinkKind::IbRdma4x,
    );
    c.set_inter(0, 2, LinkKind::IbNoRdma);
    c.set_inter(1, 2, LinkKind::IbNoRdma);
    c
}

/// FT results on a mixed-generation, mixed-intra cluster must be
/// bit-identical regardless of LDP thread count.
#[test]
fn ft_deterministic_across_thread_counts_on_mixed_cluster() {
    let cluster = mixed_small();
    let g = models::tiny_mlp(128);
    let comm = CommModel::profile(&cluster);
    let d = cluster.n_devices() as u32;
    let seq = frontier_search(&g, &cluster, &comm, FtOptions::new(d).sequential());
    assert!(!seq.frontier.is_empty());
    for threads in [2usize, 4, 8] {
        let mut opts = FtOptions::new(d);
        opts.threads = threads;
        let par = frontier_search(&g, &cluster, &comm, opts);
        assert_eq!(seq.frontier.len(), par.frontier.len(), "threads={threads}");
        for (a, b) in seq.frontier.tuples.iter().zip(&par.frontier.tuples) {
            assert_eq!((a.mem, a.time), (b.mem, b.time), "threads={threads}");
        }
    }
}

/// Prefix sub-allocations keep every machine's own spec and intra link,
/// and the memory floor follows the smallest device actually in the set.
#[test]
fn sub_cluster_preserves_specs_and_links() {
    let c = Cluster::big_little();
    let sub = c.sub_cluster(9); // 8 A100 + 1 V100
    assert_eq!(sub.n_devices(), 9);
    assert_eq!(sub.n_machines(), 2);
    assert_eq!(sub.device_at(0).gen, "A100");
    assert_eq!(sub.device_at(8).gen, "V100");
    assert_eq!(sub.machines[1].intra, LinkKind::Pcie);
    assert_eq!(sub.min_device_memory(), DeviceSpec::v100().memory);
    // dropping the little machine entirely lifts the memory floor.
    let big_only = c.sub_cluster(8);
    assert_eq!(big_only.n_machines(), 1);
    assert_eq!(big_only.min_device_memory(), DeviceSpec::a100().memory);
}

/// Arbitrary machine subsets preserve per-machine specs and the pairwise
/// links between the machines kept.
#[test]
fn select_machines_preserves_pairwise_links() {
    let c = straggler_small();
    let slow_pair = c.select_machines(&[0, 2]);
    assert_eq!(slow_pair.n_machines(), 2);
    // the original 0-2 link becomes the subset's 0-1 link.
    assert_eq!(
        slow_pair.inter_between(0, 1).bandwidth,
        LinkKind::IbNoRdma.link().bandwidth
    );
    let fast_pair = c.select_machines(&[0, 1]);
    assert_eq!(
        fast_pair.inter_between(0, 1).bandwidth,
        LinkKind::IbRdma4x.link().bandwidth
    );
    for m in &slow_pair.machines {
        assert_eq!(m.device.gen, "V100");
        assert_eq!(m.gpus, 2);
        assert_eq!(m.intra, LinkKind::NvLink);
    }
    // subset bottlenecks reflect only the links kept.
    assert_eq!(slow_pair.inter_link().bandwidth, LinkKind::IbNoRdma.link().bandwidth);
    assert_eq!(fast_pair.inter_link().bandwidth, LinkKind::IbRdma4x.link().bandwidth);
}

/// The mechanism behind the `exp hetero` headline, asserted strictly:
/// whenever the optimistic (homogenized) belief picks a different solo
/// parallelism than the topology-aware one, executing the aware pick on
/// the real cluster must be strictly faster than executing the optimistic
/// pick — that per-job gap is what the aware scheduler's makespan win on
/// the straggler testbed is made of. (Guarded like the elastic-vs-static
/// strict test in tests/sched.rs: if both beliefs happen to agree at this
/// scale, the full-size `exp hetero` run carries the claim.)
#[test]
fn straggler_optimistic_pick_strictly_loses_when_beliefs_diverge() {
    let cluster = straggler_small();
    let ladder = SchedConfig::for_cluster(&cluster).ladder;
    let aware_cache = FrontierCache::new(cluster.clone());
    let homo_cache = FrontierCache::with_assumption(cluster.clone(), cluster.homogenized());
    let aware = aware_cache.curve("tiny", 256, &ladder);
    let homo = homo_cache.curve("tiny", 256, &ladder);
    let pick = |c: &ProfileCurve| -> u32 {
        ladder
            .iter()
            .copied()
            .filter(|&d| c.est_time(d).is_some())
            .min_by(|&a, &b| {
                c.est_time(a).unwrap().partial_cmp(&c.est_time(b).unwrap()).unwrap()
            })
            .expect("tiny model is feasible somewhere")
    };
    let (pa, ph) = (pick(&aware), pick(&homo));
    // the optimistic belief can never make the crossing parallelism look
    // slower than the aware one does.
    let d_full = cluster.n_devices() as u32;
    let (ea, eh) = (aware.est_time(d_full).unwrap(), homo.est_time(d_full).unwrap());
    assert!(eh <= ea * 1.0001, "homogenized est {eh} vs aware est {ea}");
    if pa != ph {
        let gt_aware = aware.iter_time(pa, true).unwrap();
        let gt_homo = homo.iter_time(ph, true).unwrap();
        assert!(
            gt_aware < gt_homo,
            "aware pick {pa} ({gt_aware}s/iter) must strictly beat the \
             optimistic pick {ph} ({gt_homo}s/iter) on the real cluster"
        );
    }
}

/// Same workload, same cluster, same ground truth — the scheduler that
/// knows the topology must not do worse than the one assuming the fabric
/// is uniform.
#[test]
fn straggler_aware_scheduler_not_worse_than_homogeneous_assumption() {
    let cluster = straggler_small();
    let jobs = Workload::synthetic(3, &[("tiny", 256)], 0.01, (2000, 4000), 7);
    let cfg = SchedConfig::for_cluster(&cluster);
    let aware_cache = FrontierCache::new(cluster.clone());
    let homo_cache = FrontierCache::with_assumption(cluster.clone(), cluster.homogenized());
    let aware = run_workload(&jobs, &cluster, Policy::ElasticFrontier, &aware_cache, &cfg);
    let homo = run_workload(&jobs, &cluster, Policy::ElasticFrontier, &homo_cache, &cfg);
    assert!(aware.makespan > 0.0 && homo.makespan > 0.0);
    assert!(
        aware.makespan <= homo.makespan * 1.10,
        "aware {} vs homogeneous-assumed {}",
        aware.makespan,
        homo.makespan
    );
    for r in [&aware, &homo] {
        assert!(r.unschedulable.is_empty());
        assert!(r.peak_devices as usize <= cluster.n_devices());
    }
}
