//! End-to-end tests of the multi-job cluster scheduler: a 3-job workload
//! through the discrete-event timeline under every policy, allocator
//! invariants at the workload level, and determinism.

use tensoropt::cluster::Cluster;
use tensoropt::sched::{
    run_workload, FrontierCache, JobSpec, Policy, RescaleModel, SchedConfig,
};

const N_GPUS: usize = 8;

fn setup() -> (Cluster, FrontierCache, SchedConfig) {
    let cluster = Cluster::with_gpus(N_GPUS);
    let cache = FrontierCache::new(cluster.clone());
    let mut cfg = SchedConfig::for_cluster(&cluster);
    // tiny-model iterations are sub-millisecond, so scale the rescale
    // overhead down to keep the same overhead-to-runtime ratio a real
    // cluster would see.
    cfg.rescale = RescaleModel { base_s: 1e-3, reshard_bw: 10e9 };
    (cluster, cache, cfg)
}

/// 3 jobs, staggered arrivals. Iteration counts are calibrated from the
/// frontier itself (~`target_s` seconds at the floor parallelism) so the
/// workload shape is stable even if the cost model is retuned.
fn three_jobs(cache: &FrontierCache, cfg: &SchedConfig, target_s: f64) -> Vec<JobSpec> {
    let specs = [("tiny", 256i64), ("tiny", 128), ("tiny", 64)];
    specs
        .iter()
        .enumerate()
        .map(|(i, &(model, batch))| {
            let curve = cache.curve(model, batch, &cfg.ladder);
            let floor = curve.floor().expect("tiny models always fit");
            let it = curve.est_time(floor).unwrap();
            JobSpec {
                id: i,
                name: format!("job{i}"),
                model: model.to_string(),
                batch,
                iterations: ((target_s / it).ceil() as u64).max(1),
                priority: 1.0,
                arrival: i as f64 * target_s * 0.1,
                budget_usd: None,
                deadline_s: None,
            }
        })
        .collect()
}

#[test]
fn three_job_workload_end_to_end_under_every_policy() {
    let (cluster, cache, cfg) = setup();
    let jobs = three_jobs(&cache, &cfg, 30.0);
    for policy in Policy::all() {
        let r = run_workload(&jobs, &cluster, policy, &cache, &cfg);
        assert!(r.unschedulable.is_empty(), "{policy:?}: {:?}", r.unschedulable);
        assert_eq!(r.outcomes.len(), 3);
        for o in &r.outcomes {
            assert!(o.start.is_some(), "{policy:?}: {} never started", o.job.name);
            assert!(o.finish > o.job.arrival, "{policy:?}: {} bad finish", o.job.name);
        }
        // hard allocator invariant, observed at workload level.
        assert!(
            r.peak_devices as usize <= N_GPUS,
            "{policy:?} allocated {} devices on {N_GPUS}",
            r.peak_devices
        );
        assert!(r.utilization > 0.0 && r.utilization <= 1.0 + 1e-9, "{policy:?}");
        assert!(r.makespan >= r.outcomes.iter().map(|o| o.jct).fold(0.0, f64::max) * 0.99);
    }
}

#[test]
fn elastic_frontier_beats_or_matches_static_equal_share() {
    let (cluster, cache, cfg) = setup();
    let jobs = three_jobs(&cache, &cfg, 30.0);
    let e = run_workload(&jobs, &cluster, Policy::ElasticFrontier, &cache, &cfg);
    let s = run_workload(&jobs, &cluster, Policy::StaticEqual, &cache, &cfg);
    // allocation decides on estimates while the timeline runs on simulated
    // ground truth, so marginal upgrades can invert by a few percent —
    // hence the slack on the "never worse" half of the assertion.
    assert!(
        e.mean_jct <= s.mean_jct * 1.10,
        "elastic mean JCT {} vs static {}",
        e.mean_jct,
        s.mean_jct
    );
    // when the model actually converts extra devices into throughput, the
    // win must be strict: the elastic policy runs early arrivals on the
    // whole (otherwise idle) cluster while static shares sit reserved.
    let curve = cache.curve("tiny", 256, &cfg.ladder);
    let floor_tp = curve.throughput(curve.floor().unwrap());
    let best_tp = cfg
        .ladder
        .iter()
        .map(|&d| curve.throughput(d))
        .fold(0.0, f64::max);
    if best_tp > 1.3 * floor_tp {
        assert!(
            e.mean_jct < s.mean_jct,
            "scalable workload but no elastic win: {} vs {}",
            e.mean_jct,
            s.mean_jct
        );
    }
}

#[test]
fn elastic_frontier_not_worse_than_fifo_on_mean_jct() {
    let (cluster, cache, cfg) = setup();
    let jobs = three_jobs(&cache, &cfg, 30.0);
    let e = run_workload(&jobs, &cluster, Policy::ElasticFrontier, &cache, &cfg);
    let f = run_workload(&jobs, &cluster, Policy::FifoExclusive, &cache, &cfg);
    assert!(
        e.mean_jct <= f.mean_jct * 1.10,
        "elastic {} vs fifo {}",
        e.mean_jct,
        f.mean_jct
    );
}

#[test]
fn workload_simulation_is_deterministic() {
    let (cluster, cache, cfg) = setup();
    let jobs = three_jobs(&cache, &cfg, 20.0);
    let a = run_workload(&jobs, &cluster, Policy::ElasticFrontier, &cache, &cfg);
    // run again against a *fresh* cache: identical results prove both the
    // FT search and the timeline are deterministic and cache-independent.
    let cache2 = FrontierCache::new(cluster.clone());
    let jobs2 = three_jobs(&cache2, &cfg, 20.0);
    let b = run_workload(&jobs2, &cluster, Policy::ElasticFrontier, &cache2, &cfg);
    assert_eq!(a.outcomes.len(), b.outcomes.len());
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.job.iterations, y.job.iterations, "calibration differs");
        assert_eq!(x.finish, y.finish, "timeline differs for {}", x.job.name);
        assert_eq!(x.n_rescales, y.n_rescales);
    }
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.total_rescales, b.total_rescales);
}

#[test]
fn shared_cache_dedupes_ft_searches_across_jobs_and_policies() {
    let (cluster, cache, cfg) = setup();
    let jobs = three_jobs(&cache, &cfg, 10.0);
    let misses_after_calibration = cache.stats().misses;
    for policy in Policy::all() {
        run_workload(&jobs, &cluster, policy, &cache, &cfg);
    }
    let stats = cache.stats();
    assert_eq!(
        stats.misses, misses_after_calibration,
        "policy runs must be pure cache hits"
    );
    assert!(stats.hits > 0);
}
