//! Microbenchmarks of the hot paths: frontier reduce/product, re-schedule
//! Dijkstra, ring vs naive all-reduce, PJRT kernel dispatch.
use tensoropt::frontier::{reduce, Mode, Trace, Tuple};
use tensoropt::runtime::collective::{all_reduce_naive, all_reduce_ring};
use tensoropt::runtime::HostTensor;
use tensoropt::util::benchkit::Bench;
use tensoropt::util::rng::XorShift;

fn main() {
    let mut b = Bench::new("micro");

    // frontier reduce on 10k random tuples
    let mut rng = XorShift::new(1);
    let tuples: Vec<Tuple> =
        (0..10_000).map(|_| Tuple::new(rng.f64(), rng.f64(), Trace::empty())).collect();
    b.run("reduce_10k", || reduce(tuples.clone(), Mode::Pareto));

    // frontier product 256 x 64
    let a = reduce((0..2048).map(|_| Tuple::new(rng.f64(), rng.f64(), Trace::empty())).collect(), Mode::Pareto);
    let c = reduce((0..512).map(|_| Tuple::new(rng.f64(), rng.f64(), Trace::empty())).collect(), Mode::Pareto);
    b.run("product", || a.product(&c, Mode::Pareto));

    // collectives: 8 devices x 4 MB
    for (name, ring) in [("allreduce_naive_8x1M", false), ("allreduce_ring_8x1M", true)] {
        b.run(name, || {
            let mut bufs: Vec<HostTensor> = (0..8)
                .map(|d| HostTensor::f32(vec![1 << 20], vec![d as f32; 1 << 20]))
                .collect();
            if ring { all_reduce_ring(&mut bufs) } else { all_reduce_naive(&mut bufs) };
            bufs
        });
    }

    // PJRT kernel dispatch (Pallas matmul artifact), if built.
    let dir = tensoropt::runtime::default_artifacts_dir();
    if dir.join("matmul_256x256x256.hlo.txt").exists() {
        let mut rt = tensoropt::runtime::Runtime::cpu(&dir).unwrap();
        let exe = rt.load("matmul_256x256x256").unwrap();
        let x = HostTensor::f32(vec![256, 256], vec![1.0; 256 * 256]);
        let y = HostTensor::f32(vec![256, 256], vec![2.0; 256 * 256]);
        b.run("pjrt_pallas_matmul_256", || exe.run(&[x.clone(), y.clone()]).unwrap());
    }
    b.finish();
}
