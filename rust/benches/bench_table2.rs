//! Bench + regeneration of Table 2 (estimation error, 20 random strategies).
use tensoropt::util::benchkit::Bench;

fn main() {
    let mut b = Bench::new("table2").slow();
    b.min_iters = 1;
    b.max_iters = 1;
    b.run("table2_20_samples", || tensoropt::exp::table2::run(20));
    let t = tensoropt::exp::table2::run(20);
    println!("\n{}", t.render());
    let _ = t.save_csv(tensoropt::exp::results_dir().join("table2.csv").to_str().unwrap());
    b.finish();
}
