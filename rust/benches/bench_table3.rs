//! Table 3 IS a timing table: FT-LDP vs FT-Elimination vs single-thread.
//! Pass --full (via BENCH_TABLE3_FULL=1) to include WideResNet elimination.
fn main() {
    let full = std::env::var("BENCH_TABLE3_FULL").is_ok();
    let t = tensoropt::exp::table3::run(full);
    println!("{}", t.render());
    let _ = t.save_csv(tensoropt::exp::results_dir().join("table3.csv").to_str().unwrap());
}
