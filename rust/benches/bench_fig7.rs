//! Bench + regeneration of Figure 7 (model size / inter-bw / intra-bw sweeps).
use tensoropt::util::benchkit::Bench;

fn main() {
    let mut b = Bench::new("fig7").slow();
    b.min_iters = 1;
    b.max_iters = 1;
    b.run("fig7a_model_size", || tensoropt::exp::fig7::run_a());
    b.run("fig7b_cross_machine_bw", || tensoropt::exp::fig7::run_b());
    b.run("fig7c_intra_machine", || tensoropt::exp::fig7::run_c());
    for (t, name) in [
        (tensoropt::exp::fig7::run_a(), "fig7a"),
        (tensoropt::exp::fig7::run_b(), "fig7b"),
        (tensoropt::exp::fig7::run_c(), "fig7c"),
    ] {
        println!("\n{}", t.render());
        let _ = t.save_csv(tensoropt::exp::results_dir().join(format!("{name}.csv")).to_str().unwrap());
    }
    b.finish();
}
