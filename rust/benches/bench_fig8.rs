//! Bench + regeneration of Figure 8 (parallelism sweep).
use tensoropt::util::benchkit::Bench;

fn main() {
    let mut b = Bench::new("fig8").slow();
    b.min_iters = 1;
    b.max_iters = 1;
    // (transformer gets the full sweep; WideResNet a reduced one — its
    // 32-GPU search is the most expensive single FT run in the suite.)
    for (model, para) in [
        ("transformer", &[4u32, 8, 16, 24, 32][..]),
        ("wideresnet", &[8u32, 16][..]),
    ] {
        b.run(&format!("fig8_{model}"), || tensoropt::exp::fig8::run(model, para));
        let t = tensoropt::exp::fig8::run(model, para);
        println!("\n{}", t.render());
        let _ = t.save_csv(
            tensoropt::exp::results_dir().join(format!("fig8_{model}.csv")).to_str().unwrap(),
        );
    }
    b.finish();
}
