//! Planner-engine benches: what memoization, incremental re-search and the
//! plan store buy on the repo's hottest path.
//!
//! Two groups (two JSON files for the CI regression gate):
//! - `plan_cold_vs_warm` — one search cold (fresh planner) vs warm (memo
//!   hit), vs incremental re-billing, vs a plan-store load.
//! - `profile_sweep_shared_space` — a 4-parallelism `Session::profile`
//!   sweep through one shared planner, and the scheduler-cache curve that
//!   follows it for free.

use std::sync::Arc;

use tensoropt::cluster::Cluster;
use tensoropt::coordinator::Session;
use tensoropt::cost::pricing::Billing;
use tensoropt::graph::models::tiny_mlp;
use tensoropt::plan::{PlanRequest, Planner};
use tensoropt::sched::FrontierCache;
use tensoropt::util::benchkit::Bench;

fn main() {
    let cluster = Cluster::with_gpus(8);
    let parallelisms = [1u32, 2, 4, 8];

    // ---------------------------------------------- plan_cold_vs_warm
    let mut b = Bench::new("plan_cold_vs_warm");

    b.run("plan_cold_tiny_d8", || {
        let p = Planner::new();
        let fp = p.register_cluster(&cluster);
        p.plan(&PlanRequest::builder("tiny", 256, &fp, 8).build().unwrap()).unwrap().frontier().len()
    });

    let warm = Planner::new();
    let warm_fp = warm.register_cluster(&cluster);
    let warm_req = PlanRequest::builder("tiny", 256, &warm_fp, 8).build().unwrap();
    warm.plan(&warm_req).unwrap();
    b.run("plan_warm_memo_hit", || warm.plan(&warm_req).unwrap().frontier().len());

    // Pre-warm one planner per measured iteration so the timed closure
    // runs ONLY the incremental path (same leaves + recorded elimination
    // structure, new dollar stamps: frontier algebra + LDP). A fresh
    // planner per pull keeps every timed plan() a true re-bill, never a
    // memo hit; the pool is sized past benchkit's max iteration count.
    let mut rebill_pool: Vec<(Planner, PlanRequest)> = (0..8)
        .map(|_| {
            let p = Planner::new();
            let fp = p.register_cluster(&cluster);
            let req = PlanRequest::builder("tiny", 256, &fp, 8).build().unwrap();
            p.plan(&req).unwrap();
            (p, req)
        })
        .collect();
    let mut b_inc = Bench::new("plan_cold_vs_warm_incremental");
    b_inc.min_iters = 2;
    b_inc.target_secs = 0.0;
    b_inc.max_iters = rebill_pool.len();
    b_inc.warmup_iters = 0;
    b_inc.run("plan_incremental_rebill", || {
        let (p, req) = rebill_pool.pop().expect("pool sized past max_iters");
        p.plan(&req.to_builder().billing(Billing::Spot).build().unwrap())
            .unwrap()
            .frontier()
            .len()
    });
    b_inc.finish();

    let store_dir = std::env::temp_dir().join("tensoropt_bench_plan_store");
    let store_path = store_dir.join("plans.json");
    let _ = std::fs::remove_file(&store_path);
    {
        let seed = Planner::new();
        seed.attach_store(&store_path).unwrap();
        let fp = seed.register_cluster(&cluster);
        seed.plan(&PlanRequest::builder("tiny", 256, &fp, 8).build().unwrap()).unwrap();
        seed.flush_store().unwrap();
    }
    b.run("plan_store_restart_serve", || {
        let p = Planner::new();
        p.attach_store(&store_path).unwrap();
        let fp = p.register_cluster(&cluster);
        p.plan(&PlanRequest::builder("tiny", 256, &fp, 8).build().unwrap()).unwrap().frontier().len()
    });
    b.finish();

    // ---------------------------------------- profile_sweep_shared_space
    let mut b2 = Bench::new("profile_sweep_shared_space");

    b2.run("profile_sweep_4p_shared_space", || {
        let planner = Arc::new(Planner::new());
        let session =
            Session::builder(tiny_mlp(256), cluster.clone()).planner(planner).build();
        session.profile(&parallelisms).len()
    });

    let shared = Arc::new(Planner::new());
    let session = Session::builder(tiny_mlp(256), cluster.clone())
        .planner(Arc::clone(&shared))
        .build();
    session.profile(&parallelisms);
    b2.run("curve_after_profile_all_warm", || {
        // the scheduler cache re-reads the session's searches: planner memo
        // hits + one simulation per point.
        let cache = FrontierCache::new_shared(cluster.clone(), Arc::clone(&shared));
        cache.curve("tiny", 256, &parallelisms).points.len()
    });
    b2.finish();

    let _ = std::fs::remove_file(&store_path);
}
