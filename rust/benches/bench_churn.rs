//! Churn-engine benches: re-plan latency and degradation health under a
//! seeded fault trace.
//!
//! Group `churn_replan` (one JSON file for the CI regression gate):
//! - `churn_replan_p50` / `churn_replan_p99` — quantiles of the
//!   `churn.replan_latency` histogram after a seeded elastic replay with
//!   a shallow admission queue (so the path includes store fills, sheds
//!   and retries, not just memo hits).
//! - `churn_fallback_rate` — shed re-plans over total re-plans of that
//!   replay. The gate only flags increases: more of the timeline spent
//!   on degraded stale plans is a regression even if latency holds.
//! - `churn_replay_small` — wall time of a minimal end-to-end replay
//!   (trace generation + both policies), the whole-engine cost anchor.

use tensoropt::cluster::{Cluster, DeviceSpec, LinkKind, Machine};
use tensoropt::obs;
use tensoropt::sched::{run_churn, ChurnCfg, ChurnPolicy, ChurnTrace, Workload};
use tensoropt::util::benchkit::Bench;

fn cluster() -> Cluster {
    Cluster::from_machines(
        "bench-churn-2x2",
        vec![
            Machine::new(DeviceSpec::v100(), 2, LinkKind::NvLink),
            Machine::new(DeviceSpec::v100(), 2, LinkKind::NvLink),
        ],
        LinkKind::IbRdma,
    )
}

fn main() {
    let mut b = Bench::new("churn_replan");

    let base = cluster();
    let cfg = ChurnCfg {
        n_events: 5,
        horizon_s: 30.0,
        tick_s: 0.5,
        queue_depth: 1,
        ..ChurnCfg::default()
    };
    let jobs = Workload::synthetic(3, &[("tiny", 128), ("tiny", 64)], 1.0, (400, 1200), 7);
    let trace = ChurnTrace::generate(&cfg, base.n_machines());
    let report = run_churn(&jobs, &base, &trace, ChurnPolicy::Elastic, &cfg);
    println!(
        "elastic replay: {}/{} done, {} replans ({} degraded), {} events",
        report.completed,
        report.n_jobs,
        report.replans,
        report.fallback_replans,
        report.events_applied
    );
    let h = obs::global_metrics()
        .histogram("churn.replan_latency")
        .expect("the replay observed re-plan latencies");
    b.record("churn_replan_p50", h.quantile(0.50));
    b.record("churn_replan_p99", h.quantile(0.99));
    b.record(
        "churn_fallback_rate",
        report.fallback_replans as f64 / report.replans.max(1) as f64,
    );

    // Whole-engine anchor: a minimal replay end to end, both policies.
    let small_cfg = ChurnCfg {
        n_events: 2,
        horizon_s: 10.0,
        tick_s: 0.5,
        ..ChurnCfg::default()
    };
    let small_jobs = Workload::synthetic(2, &[("tiny", 64)], 1.0, (200, 400), 7);
    b.run("churn_replay_small", || {
        let trace = ChurnTrace::generate(&small_cfg, base.n_machines());
        let e = run_churn(&small_jobs, &base, &trace, ChurnPolicy::Elastic, &small_cfg);
        let s = run_churn(&small_jobs, &base, &trace, ChurnPolicy::Static, &small_cfg);
        e.completed + s.completed
    });

    b.finish();
}
