//! Bench + regeneration of Figure 6 (cost frontiers + baselines) for the
//! three large models.
use tensoropt::util::benchkit::Bench;

fn main() {
    let mut b = Bench::new("fig6").slow();
    b.min_iters = 1;
    b.max_iters = 1;
    for model in ["rnn", "transformer", "wideresnet"] {
        b.run(&format!("fig6_{model}"), || tensoropt::exp::fig6::run(model, 16));
        let (curve, summary) = tensoropt::exp::fig6::run(model, 16);
        println!("\n{}", summary.render());
        let dir = tensoropt::exp::results_dir();
        let _ = curve.save_csv(dir.join(format!("fig6_{model}_curve.csv")).to_str().unwrap());
        let _ = summary.save_csv(dir.join(format!("fig6_{model}_summary.csv")).to_str().unwrap());
    }
    b.finish();
}
