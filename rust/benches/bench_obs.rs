//! Recorder-overhead benches: what tracing costs on the hottest planner
//! path, and what a *disabled* recorder costs (the answer must be: one
//! relaxed atomic load, i.e. nothing).
//!
//! Group `obs_overhead` (one JSON file for the CI regression gate):
//! - `plan_cold_recorder_off` / `plan_cold_recorder_on` — the same cold
//!   `tiny@d8` search untraced vs fully traced (spans, events, metrics).
//!   The traced run must stay within a few percent of the untraced one.
//! - `span_guard_disabled_x1000` — 1000 disabled `obs::span` calls; pins
//!   the "recorder off" fast path at noise level.

use tensoropt::cluster::Cluster;
use tensoropt::obs;
use tensoropt::plan::{PlanRequest, Planner};
use tensoropt::util::benchkit::Bench;

fn plan_cold(cluster: &Cluster) -> usize {
    let p = Planner::new();
    let fp = p.register_cluster(cluster);
    p.plan(&PlanRequest::builder("tiny", 256, &fp, 8).build().unwrap()).unwrap().frontier().len()
}

fn main() {
    let cluster = Cluster::with_gpus(8);
    let mut b = Bench::new("obs_overhead");

    obs::disable();
    let off = b.run("plan_cold_recorder_off", || plan_cold(&cluster)).mean_s;

    obs::enable();
    let on = b.run("plan_cold_recorder_on", || plan_cold(&cluster)).mean_s;
    // don't let the accumulated records leak into later measurements.
    let drained = obs::global().drain();
    obs::disable();

    b.run("span_guard_disabled_x1000", || {
        let mut n = 0usize;
        for _ in 0..1000 {
            let sp = obs::span("bench.noop");
            if sp.active() {
                n += 1;
            }
        }
        n
    });
    b.finish();

    println!(
        "traced cold plan recorded {} records; overhead {:+.2}% vs untraced",
        drained.len(),
        100.0 * (on - off) / off
    );
}
