//! Bench + regeneration of Table 4 (real-executor per-iteration times).
//! Requires `make artifacts`.
fn main() {
    if !tensoropt::runtime::default_artifacts_dir().join("manifest.txt").exists() {
        eprintln!("skipping table4 bench: run `make artifacts` first");
        return;
    }
    let t = tensoropt::exp::table4::run(2, 30).expect("table4");
    println!("{}", t.render());
    let _ = t.save_csv(tensoropt::exp::results_dir().join("table4.csv").to_str().unwrap());
}
