//! `bench_pipe` (ISSUE 10): the interval-memoized pipeline cut sweep vs
//! brute-force enumeration with per-cut cold stage searches, on a
//! 12-layer transformer across 8 devices (10 cut candidates, up to 4
//! stages). Both legs run single-threaded so the in-artifact
//! `pipe_memo_over_cold_ratio` is purely algorithmic — interval table +
//! schedule replay vs recomputation — not a parallelism artifact.
//! `BENCH_QUICK` shrinks the tensor extents only; the spine and therefore
//! the cut/stage structure is identical in both modes.

use tensoropt::cluster::Cluster;
use tensoropt::frontier::Mode;
use tensoropt::ft::pipeline::{self, ColdSweepCtx, PipelineOpts};
use tensoropt::graph::models::{transformer_lm, TransformerCfg};
use tensoropt::plan::{PipelineRequest, PlanRequest, Planner};
use tensoropt::util::benchkit::Bench;

fn main() {
    let mut b = Bench::new("pipe").slow();
    let quick = std::env::var("BENCH_QUICK").is_ok_and(|v| !v.is_empty() && v != "0");
    let graph = transformer_lm(TransformerCfg {
        batch: 8,
        seq: if quick { 4 } else { 16 },
        hidden: if quick { 32 } else { 128 },
        ffn_mult: 2,
        layers: 12,
        vocab: if quick { 64 } else { 512 },
    });
    let cluster = Cluster::with_gpus(8);
    let opts =
        PipelineOpts { max_stages: 4, micro_batches: 8, max_cuts: 10, mode: Mode::Pareto };

    // Memoized leg: a fresh planner per iteration — every sweep pays its
    // own interval extraction, leaf builds, and first-cold/rest-replayed
    // stage searches, exactly once per (interval, width).
    let memo = b
        .run("memo_sweep_transformer12", || {
            let planner = Planner::new().with_threads(1);
            let fp = planner.register_cluster(&cluster);
            let (id, batch) = planner.register_graph(graph.clone());
            let preq = PipelineRequest::new(
                PlanRequest::builder(&id, batch, &fp, 8)
                    .threads(1)
                    .build()
                    .expect("bench request is valid"),
            )
            .with_max_stages(opts.max_stages)
            .with_micro_batches(opts.micro_batches)
            .with_max_cuts(opts.max_cuts);
            planner.plan_pipeline(&preq).expect("bench sweep plans").frontier.len()
        })
        .mean_s;

    // Cold leg: enumerate every cut vector and search each of its stages
    // from scratch — the naive sweep the interval table replaces.
    let spine = graph.mark_linear_spine();
    let ctx = ColdSweepCtx {
        graph: &graph,
        spine: &spine,
        cluster: &cluster,
        devices: 8,
        max_mesh_dims: 2,
        threads: 1,
        billing: None,
    };
    let cold =
        b.run("cold_sweep_transformer12", || pipeline::brute_force_sweep(&ctx, &opts).len())
            .mean_s;

    // bigger-is-better ratio: the armed gate fails if the memoized sweep
    // drops below 3x the brute-force cost (see scripts/bench_compare.py).
    b.record("pipe_memo_over_cold_ratio", cold / memo);
    b.finish();
}
