//! Bench + regeneration of Table 1 (model statistics).
use tensoropt::util::benchkit::Bench;

fn main() {
    let mut b = Bench::new("table1");
    b.run("table1_build_and_stats", || tensoropt::exp::table1::run());
    let t = tensoropt::exp::table1::run();
    println!("\n{}", t.render());
    let _ = t.save_csv(tensoropt::exp::results_dir().join("table1.csv").to_str().unwrap());
    b.finish();
}
