//! `bench_ft_large` (ISSUE 9): cold FT search throughput on a 96-layer
//! transformer at batches 32/128/512 — the struct-of-arrays rewrite's
//! tentpole target — plus side-by-side SoA vs `frontier::reference`
//! kernel timings, so every BENCH artifact carries the engine speedup
//! next to the end-to-end numbers (see README.md "Pinning the speedup"
//! for comparing against the pre-rewrite anchor commit).

use tensoropt::cluster::Cluster;
use tensoropt::cost::comm::GroundTruthComm;
use tensoropt::frontier::{reduce, reference, Mode, Trace, Tuple};
use tensoropt::ft::{frontier_search, FtOptions};
use tensoropt::graph::models::transformer96;
use tensoropt::util::benchkit::Bench;
use tensoropt::util::rng::XorShift;

fn main() {
    let mut b = Bench::new("ft_large").slow();
    let cluster = Cluster::paper_testbed();
    let comm = GroundTruthComm::new(cluster.clone());

    // ---- end-to-end cold searches (space build + elimination + LDP).
    for batch in [32i64, 128, 512] {
        let g = transformer96(batch);
        b.run(&format!("cold_search_transformer96_b{batch}"), || {
            let mut opts = FtOptions::new(4);
            opts.threads = 8;
            frontier_search(&g, &cluster, &comm, opts).frontier.len()
        });
    }

    // ---- SoA kernel vs the frozen pre-SoA oracle on one shared cloud:
    // the in-artifact speedup anchor for the rewrite itself.
    let mut rng = XorShift::new(7);
    let cloud: Vec<Tuple> = (0..50_000)
        .map(|_| Tuple::with_cost(rng.f64() * 1e9, rng.f64(), rng.f64(), Trace::empty()))
        .collect();
    let soa = b.run("reduce_50k_soa", || reduce(cloud.clone(), Mode::Pareto)).mean_s;
    let old = b
        .run("reduce_50k_reference", || reference::reduce(cloud.clone(), Mode::Pareto))
        .mean_s;

    let a = reduce(cloud[..1500].to_vec(), Mode::Pareto);
    let c = reduce(cloud[1500..3000].to_vec(), Mode::Pareto);
    b.run("product_soa", || a.product(&c, Mode::Pareto));
    b.run("product_reference", || reference::product(&a, &c, Mode::Pareto));

    // smaller-is-better ratio, so the armed gate flags the SoA kernel
    // losing ground against the frozen oracle.
    b.record("reduce_50k_soa_over_reference_ratio", soa / old);
    b.finish();
}
