//! Serving-layer benches: tail latency and warm-hit health of the
//! multi-tenant [`PlanService`] under the synthetic heavy-tailed workload.
//!
//! Group `serve_traffic` (one JSON file for the CI regression gate):
//! - `serve_p50_latency` / `serve_p95_latency` / `serve_p99_latency` —
//!   exact per-request latency quantiles of a seeded closed-loop drive,
//!   recorded via `Bench::record` so the gate catches tail regressions.
//! - `serve_miss_rate` — 1 − warm-hit-rate of the same drive. The gate
//!   only flags increases, so a drop in warm hits (more misses) trips it.
//! - `serve_store_hit` — the store-hit fast path (no planner involvement).
//! - `serve_batch_coalesced_burst` — a fresh service absorbing a mixed
//!   parallelism burst through one coalesced sweep (planner pre-warmed, so
//!   this times the serving machinery, not the search).

use std::sync::Arc;

use tensoropt::cluster::Cluster;
use tensoropt::plan::{PlanRequest, Planner};
use tensoropt::serve::{
    drive, generate, PlanService, ServeConfig, ServeRequest, TrafficCfg,
};
use tensoropt::util::benchkit::Bench;

fn main() {
    let mut b = Bench::new("serve_traffic");

    let planner = Arc::new(Planner::new());
    let fp = planner.register_cluster(&Cluster::with_gpus(8));
    let service = Arc::new(PlanService::new(Arc::clone(&planner), ServeConfig::default()));

    // ------------------------------------------ quantiles under the zoo
    // Two (model, batch) keys keep the planner work bounded: the drive
    // measures serving overhead + memoized plans, not cold search time.
    let traffic = TrafficCfg {
        requests: 200,
        models: vec![("tiny".to_string(), 256), ("tiny".to_string(), 128)],
        ..Default::default()
    };
    let arrivals = generate(&traffic, &fp);
    let report = drive(&service, &arrivals, 4, 0.0);
    b.record("serve_p50_latency", report.latency_quantile(0.50));
    b.record("serve_p95_latency", report.latency_quantile(0.95));
    b.record("serve_p99_latency", report.latency_quantile(0.99));
    b.record("serve_miss_rate", 1.0 - report.warm_hit_rate());
    println!(
        "drive: {} requests, warm-hit {:.1}%, shed {}, wall {:.1} ms",
        report.requests,
        report.warm_hit_rate() * 100.0,
        report.shed,
        report.wall.as_secs_f64() * 1e3
    );

    // ------------------------------------------ store-hit fast path
    let hot = PlanRequest::builder("tiny", 256, &fp, 4).build().unwrap();
    service.warm(&hot).unwrap();
    let hot_req = ServeRequest::new("bench", hot);
    b.run("serve_store_hit", || {
        service.serve(&hot_req).unwrap().served().expect("warmed key hits").result.clone()
    });

    // ------------------------------------------ coalesced burst
    let burst: Vec<ServeRequest> = [1u32, 2, 4, 8, 2, 4, 8, 1]
        .iter()
        .map(|&d| {
            ServeRequest::new(
                "bench",
                PlanRequest::builder("tiny", 128, &fp, d).build().unwrap(),
            )
        })
        .collect();
    b.run("serve_batch_coalesced_burst", || {
        // fresh service (empty store) on the warm planner: every iteration
        // re-runs admission + coalescing + store fill for the whole burst.
        let svc = PlanService::new(Arc::clone(&planner), ServeConfig::default());
        svc.serve_batch(&burst).len()
    });

    b.finish();
}
